#!/usr/bin/env bash
# Mechanical regression gate: tier-1 tests + the compressed-native serve path.
#
#     bash scripts/smoke.sh [extra pytest args]
#
# Runs (1) the full tier-1 pytest suite and (2) the serving launcher on the
# smoke config — a real continuous-batching decode over CompressedTensor
# leaves — so a regression anywhere in the prefill/decode/compression stack
# fails the script even if no unit test covers it directly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

echo "== serve smoke (compressed-native) =="
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 3 \
    --prompt-len 8 --gen 8

echo "== serve smoke (dense A/B) =="
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 2 \
    --prompt-len 8 --gen 4 --dense

echo "== serve smoke (paged KV pool, undersized: exercises preemption) =="
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 4 \
    --prompt-len 6 --gen 10 --paged --page-size 2 --num-pages 10 \
    --prefill-buckets 8,16

echo "== serve smoke (dispatch forced to XLA: override plumbing) =="
REPRO_KERNEL_MODE=xla python -m repro.launch.serve --arch gpt2-paper \
    --batch 2 --requests 3 --prompt-len 8 --gen 6 --paged --page-size 4 \
    --num-pages 24

echo "== serve smoke (fused K=4 decode + chunked prefill, forced XLA) =="
REPRO_KERNEL_MODE=xla python -m repro.launch.serve --arch gpt2-paper \
    --batch 2 --requests 3 --prompt-len 20 --gen 8 --paged --page-size 4 \
    --num-pages 32 --steps-per-dispatch 4 --prefill-chunk 8

echo "== serve smoke (device scheduler: run-until-stop + refill + async) =="
# more requests than lanes so frozen lanes refill from the staged ring
# inside the dispatch; host_syncs must come in under the dispatch count
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 5 \
    --prompt-len 8 --gen 10 --paged --page-size 4 --num-pages 48 \
    --max-steps-per-dispatch 6 --staged-lanes 2 --async-stream \
  | tail -1 | python -c '
import json, sys
s = json.loads(sys.stdin.read())["summary"]
assert s["scheduler"] == "device", s
assert s["refills"] > 0, s
assert s["host_syncs"] < s["dispatches"], s
print("host_syncs:", s["host_syncs"], "refills:", s["refills"])
'

echo "== serve smoke (prefix cache + int8 pages: shared head must hit) =="
# batch=1 staggers the two admissions, so the second request's shared
# 8-token head is already indexed — a zero hit rate means the radix
# index / COW admission path regressed
python -m repro.launch.serve --arch gpt2-paper --batch 1 --requests 2 \
    --prompt-len 12 --gen 4 --paged --page-size 4 --num-pages 32 \
    --prefix-cache --kv-int8 --shared-prefix 8 \
  | tail -1 | python -c '
import json, sys
s = json.loads(sys.stdin.read())["summary"]
assert s["prefix_hits"] > 0 and s["prefix_hit_rate"] > 0, s
assert s["kv_quant"], s
print("prefix_hit_rate:", s["prefix_hit_rate"])
'

echo "== serve smoke (self-speculative: draft+verify, acceptance > 0) =="
# --dense makes the serving tree the masked-dense verifier itself, so
# every draft must be accepted — a sub-1 acceptance rate (or zero
# speculative rounds) means the draft/verify/rollback seam regressed
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 3 \
    --prompt-len 8 --gen 10 --paged --page-size 4 --num-pages 32 \
    --dense --spec-gamma 4 \
  | tail -1 | python -c '
import json, sys
s = json.loads(sys.stdin.read())["summary"]
assert s["spec_rounds"] > 0, s
assert s["acceptance_rate"] > 0, s
assert s["accepted_per_verify"] > 1, s
assert s["host_syncs"] == s["spec_rounds"], s
print("acceptance_rate:", s["acceptance_rate"],
      "accepted_per_verify:", round(s["accepted_per_verify"], 2))
'

echo "== serve smoke (mesh-native engine, degenerate 1x1 mesh) =="
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 2 \
    --prompt-len 6 --gen 6 --paged --page-size 4 --num-pages 16 --mesh 1,1

echo "== serve smoke (forced shard_map kernel route on an emulated mesh) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
JAX_PLATFORMS=cpu REPRO_KERNEL_MODE=shard_map \
python -m repro.launch.serve --arch gpt2-paper --batch 2 --requests 2 \
    --prompt-len 6 --gen 6 --paged --page-size 4 --num-pages 16 --mesh 2,4

echo "smoke OK"
