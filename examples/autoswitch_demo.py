"""Reproduce the paper's Figures 2-3 diagnosis in one script.

    PYTHONPATH=src python examples/autoswitch_demo.py

Trains the controlled task twice — dense Adam vs SR-STE-with-Adam — and
prints the variance-norm trajectory (Fig 2: SR-STE's ||v|| stays high late
in training) and the per-coordinate variance change Z_t against Adam's eps
(Fig 3: dense training's Z_t sinks below eps; that crossing is what
AutoSwitch detects).
"""
import jax
import jax.numpy as jnp

import repro.core as core
from repro.data import SyntheticTask
from repro.optim.adam import adam
from repro.optim.base import apply_updates

task = SyntheticTask(seed=0)
STEPS = 400
B2 = 0.99


def run(kind: str):
    recipe = core.make_recipe(kind, core.SparsityConfig(default=core.NMSparsity(2, 4)))
    opt = adam(3e-3, b2=B2)
    params = task.student_init(jax.random.PRNGKey(0))
    state = opt.init(params)
    rstate = recipe.init_state(params)
    d = sum(x.size for x in jax.tree_util.tree_leaves(params))
    vs, zs = [], []

    @jax.jit
    def one(params, state, rstate, x, y):
        mask, active, rstate = recipe.masks_for_step(params, rstate, jnp.asarray(True))
        g = jax.grad(lambda p: task.loss(recipe.forward_params(p, mask, active), x, y))(params)
        g = recipe.grad_postprocess(g, params, mask, active)
        v_old = state.v
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
        vnorm = jnp.sqrt(sum(jnp.sum(jnp.square(t)) for t in jax.tree_util.tree_leaves(state.v)))
        z = sum(jnp.sum(jnp.abs(a - b)) for a, b in zip(
            jax.tree_util.tree_leaves(state.v), jax.tree_util.tree_leaves(v_old))) / d
        return params, state, rstate, vnorm, z

    for t in range(STEPS):
        x, y = task.batch(t, 64)
        params, state, rstate, vnorm, z = one(params, state, rstate, x, y)
        vs.append(float(vnorm))
        zs.append(float(z))
    return vs, zs


def sparkline(xs, width=60):
    import math

    blocks = "▁▂▃▄▅▆▇█"
    xs = xs[:: max(1, len(xs) // width)]
    logs = [math.log10(max(x, 1e-12)) for x in xs]
    lo, hi = min(logs), max(logs)
    rng = max(hi - lo, 1e-9)
    return "".join(blocks[int((l - lo) / rng * (len(blocks) - 1))] for l in logs)


dense_v, dense_z = run("dense")
sr_v, sr_z = run("sr_ste")

print("Fig 2 analogue — ||v_t|| over training (log-scaled sparkline):")
print(f"  dense : {sparkline(dense_v)}  (final {dense_v[-1]:.2e})")
print(f"  sr-ste: {sparkline(sr_v)}  (final {sr_v[-1]:.2e})")
print(f"  -> SR-STE/dense final variance-norm ratio: {sr_v[-1]/dense_v[-1]:.1f}x")
print()
print("Fig 3 analogue — per-coordinate variance change Z_t vs switching eps:")
# tiny-model variance coordinates are small; scale eps off the early Z_t
# level exactly as a practitioner tunes Adam's eps to the task
eps = sorted(dense_z[:20])[10] * 0.02
print(f"  dense : {sparkline(dense_z)}  (final {dense_z[-1]:.2e}, eps {eps:.0e})")
cross = next((t for t, z in enumerate(dense_z) if z < eps), None)
print(f"  -> Z_t first crosses eps at t={cross} — AutoSwitch's switching point")
cfg = core.AutoSwitchConfig(beta2=B2, eps=eps)
t0 = core.criterion_autoswitch_offline(jnp.asarray(dense_z), cfg)
print(f"  -> AutoSwitch (window {cfg.t_w}) picks t0={t0}")
