"""Batched sparse serving demo: export Π_T ⊙ w_T, compress, decode.

    PYTHONPATH=src python examples/serve_sparse.py
    PYTHONPATH=src python examples/serve_sparse.py --ckpt-dir /tmp/train_lm_ck

Shows the deployment path: final-mask export (Algorithm 1 line 23-24),
N:M weight compression (the HBM-bandwidth win the nm_spmm Pallas kernel
realizes on TPU), and a batched KV-cache greedy-decode loop.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "gpt2-paper", "--batch", "4", "--gen", "16"])
