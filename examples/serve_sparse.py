"""Compressed-native serving demo: export Π_T ⊙ w_T, compress, serve it.

    PYTHONPATH=src python examples/serve_sparse.py
    PYTHONPATH=src python examples/serve_sparse.py --ckpt-dir /tmp/train_lm_ck

Shows the deployment path: final-mask export (Algorithm 1 line 23-24), N:M
weight compression, and a continuous-batching decode loop that consumes the
``CompressedTensor`` tree directly — every weight read goes through the
``nm_spmm`` compressed-matmul path (the HBM-bandwidth win on TPU), with no
dense rehydration. Submits more requests than decode lanes so slot reuse
(continuous batching) is exercised, and serves from the paged KV-cache
pool (`--paged --page-size/--num-pages`) with bucketed batched prefill
and the fused zero-copy decode loop (`--steps-per-dispatch 4`: four decode
steps per on-device scan, donated cache buffers, one host sync per block)
— drop the flags for the contiguous-slab / per-step baseline.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(
        sys.argv[1:]
        or ["--arch", "gpt2-paper", "--batch", "2", "--requests", "5",
            "--gen", "12", "--paged", "--page-size", "8",
            "--prefill-buckets", "8,16,32", "--steps-per-dispatch", "4"]
    )
