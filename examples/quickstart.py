"""Quickstart: learn a 2:4 mask from scratch with STEP (Algorithm 1 + 2).

    PYTHONPATH=src python examples/quickstart.py

Trains a 2-layer MLP student against an exactly-2:4-sparse teacher with the
STEP recipe, lets AutoSwitch pick the precondition/mask-learning boundary,
and exports the deployable Π_T ⊙ w_T artifact.
"""
import jax
import jax.numpy as jnp

import repro.core as core
from repro.data import DataIterator, SyntheticTask
from repro.train import Trainer, TrainerConfig

task = SyntheticTask(seed=0, n=2, m=4)

# 1. pick the sparsity pattern and the recipe (STEP = STE + precondition)
recipe = core.make_recipe("step", core.SparsityConfig(default=core.NMSparsity(2, 4)))

# 2. STEP optimizer: Adam hyperparameters + AutoSwitch (threshold = Adam eps)
step_cfg = core.StepConfig(
    learning_rate=3e-3,
    b2=0.99,
    autoswitch=core.AutoSwitchConfig(eps=5e-5, window=100, t_min=40, t_max=200),
)


def loss_fn(params, batch):
    x, y = batch
    return task.loss(params, x, y), {}


# 3. train — the Trainer wires recipe + optimizer + data + checkpoints
trainer = Trainer(
    loss_fn,
    recipe,
    step_cfg,
    DataIterator(batch_fn=task.batch, batch_size=64, prefetch=0),
    TrainerConfig(total_steps=400, log_every=50, ckpt_every=0),
    log_fn=lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.4f} phase2={bool(m['phase2'])} "
        f"z_bar={m.get('z_bar', float('nan')):.2e}"
    ),
)
state, _ = trainer.run(task.student_init(jax.random.PRNGKey(0)))

# 4. export the sparse model (Algorithm 1, line 24) and evaluate it
sparse = recipe.export_sparse(state.params)
x, y = task.batch(10**6, 2048)
print(f"\nAutoSwitch fired at t0={int(state.opt.t0)}")
print(f"sparse eval loss: {float(task.loss(sparse, x, y)):.4f}")
print(f"zeros in fc1:     {float(jnp.mean(sparse['fc1']['w'] == 0)):.2%}")
