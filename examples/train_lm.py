"""End-to-end LM training driver (paper §6 task 4 analogue).

    # CPU-scale smoke (default):
    PYTHONPATH=src python examples/train_lm.py --steps 200

    # the real ~124M GPT-2-class run (TPU-scale; same code path):
    PYTHONPATH=src python examples/train_lm.py --no-smoke --steps 300 \
        --batch 32 --seq 512 --ckpt-dir /tmp/gpt2_step

Wraps the production launcher (repro.launch.train): STEP recipe on the
GPT-2-family config, synthetic corpus, AutoSwitch, checkpoint/auto-resume.
Kill it mid-run and re-invoke with the same --ckpt-dir: it resumes exactly.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "gpt2-paper", "--steps", "200", "--ckpt-dir", "/tmp/train_lm_ck"])
