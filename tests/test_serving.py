"""Compressed-native serving: matmul dispatch, engine parity, batching.

The load-bearing guarantees: (1) ``layers.matmul`` on a ``CompressedTensor``
equals the dense matmul on the masked weight (the compress→matmul→dense
round trip), (2) the serving engine's logits from the compressed tree match
the dense forward on Π_T ⊙ w within tolerance (for 2:4 and 1:4), and
(3) continuous batching — slot reuse, per-request sampling and stop
handling — does not change any request's tokens vs serving it alone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.core.masking import nm_compress, nm_mask
from repro.models import layers as L
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import CompressedTensor, compress_params

jax.config.update("jax_platform_name", "cpu")

CFG = get_config("gpt2-paper", smoke=True)
MODEL = TransformerLM(CFG)


def _compressed_tree(n, m, seed=0):
    params = MODEL.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)  # Π_T ⊙ w
    return sparse, compress_params(sparse, recipe.sparsity)


def _ct(w, n, m, group_axis=0):
    v, i = nm_compress(w, n, m, group_axis)
    return CompressedTensor(v, i, n, m, group_axis, tuple(w.shape))


# ---------------------------------------------------------------------------
# the matmul dispatch point
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(2, 4), (1, 4)])
def test_matmul_compress_roundtrip(n, m):
    """compress → L.matmul → equals dense matmul on the masked weight."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    masked = nm_mask(w, n, m, 0) * w
    y = L.matmul(x, _ct(w, n, m))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ masked), atol=1e-4)
    # round trip back to dense
    np.testing.assert_allclose(
        np.asarray(_ct(w, n, m).dense()), np.asarray(masked), atol=0
    )


def test_matmul_dense_passthrough():
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    np.testing.assert_array_equal(np.asarray(L.matmul(x, w)), np.asarray(x @ w))


def test_matmul_3d_activations_compressed_weight():
    """(B, S, d) activations against a 2-D compressed weight."""
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 24))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 7, 32))
    masked = nm_mask(w, 2, 4, 0) * w
    y = L.matmul(x, _ct(w, 2, 4))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ masked), atol=1e-4)


def test_matmul_stacked_expert_weights():
    """(E, C, d) @ compressed (E, d, f) — the MoE / scanned-body layout."""
    e, c, d, f = 3, 5, 32, 16
    w = jax.random.normal(jax.random.PRNGKey(4), (e, d, f))
    x = jax.random.normal(jax.random.PRNGKey(5), (e, c, d))
    masked = nm_mask(w, 2, 4, -2) * w
    y = L.matmul(x, _ct(w, 2, 4, group_axis=-2))
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.einsum("ecd,edf->ecf", x, masked)), atol=1e-4
    )


def test_compressed_tensor_flows_through_jit_and_scan():
    """Static (n, m) metadata survives jit; children scan over the lead axis."""
    w = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 8))
    ct = _ct(w, 2, 4, group_axis=-2)

    @jax.jit
    def f(ct, x):
        def body(carry, layer_ct):
            return carry + jnp.sum(L.matmul(x, layer_ct)), None

        out, _ = jax.lax.scan(body, jnp.zeros(()), ct)
        return out

    x = jax.random.normal(jax.random.PRNGKey(7), (3, 16))
    expected = sum(
        float(jnp.sum(x @ (nm_mask(w[i], 2, 4, 0) * w[i]))) for i in range(4)
    )
    np.testing.assert_allclose(float(f(ct, x)), expected, rtol=1e-5)


# ---------------------------------------------------------------------------
# serving parity: compressed tree vs dense forward on the masked weights
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(2, 4), (1, 4)])
def test_compressed_decode_matches_masked_dense(n, m):
    """prefill + decode_step on the CompressedTensor tree reproduce the
    dense path on Π_T ⊙ w within tolerance."""
    sparse, comp = _compressed_tree(n, m)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, CFG.vocab)
    ld, cd = MODEL.prefill(sparse, {"tokens": toks}, max_len=12, chunk=8)
    lc, cc = MODEL.prefill(comp, {"tokens": toks}, max_len=12, chunk=8)
    np.testing.assert_allclose(
        np.asarray(ld, np.float32), np.asarray(lc, np.float32), atol=5e-2
    )
    tok = jnp.argmax(lc, -1)
    for _ in range(3):
        ld, cd = MODEL.decode_step(sparse, tok, cd)
        lc, cc = MODEL.decode_step(comp, tok, cc)
        np.testing.assert_allclose(
            np.asarray(ld, np.float32), np.asarray(lc, np.float32), atol=5e-2
        )
        tok = jnp.argmax(lc, -1)


def test_engine_greedy_matches_direct_decode_loop():
    """The engine (1 lane) reproduces a hand-rolled greedy KV-cache loop."""
    _, comp = _compressed_tree(2, 4)
    prompt = [int(t) for t in
              jax.random.randint(jax.random.PRNGKey(2), (6,), 0, CFG.vocab)]
    gen = 5

    logits, cache = MODEL.prefill(
        comp, {"tokens": jnp.asarray(prompt)[None]}, max_len=16
    )
    tok = jnp.argmax(logits, -1)
    expected = [int(tok[0])]
    for _ in range(gen - 1):
        logits, cache = MODEL.decode_step(comp, tok, cache)
        tok = jnp.argmax(logits, -1)
        expected.append(int(tok[0]))

    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=16)
    uid = eng.submit(prompt, SamplingParams(max_new_tokens=gen))
    res = eng.run()[uid]
    assert res.tokens == expected
    assert res.finish_reason == "length"


# ---------------------------------------------------------------------------
# continuous batching / scheduling
# ---------------------------------------------------------------------------


def _solo_tokens(comp, prompt, sp):
    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=24)
    uid = eng.submit(prompt, sp)
    return eng.run()[uid].tokens


def test_continuous_batching_matches_solo_runs():
    """5 requests over 2 lanes: slots are reused and every request's greedy
    tokens equal its solo (batch-of-1) serve."""
    _, comp = _compressed_tree(2, 4)
    key = jax.random.PRNGKey(3)
    reqs = []
    for r in range(5):
        key, sub = jax.random.split(key)
        prompt = [int(t) for t in jax.random.randint(sub, (6,), 0, CFG.vocab)]
        reqs.append((prompt, SamplingParams(max_new_tokens=3 + 2 * (r % 3))))

    eng = DecodeEngine(MODEL, comp, max_batch=2, max_len=24)
    uids = [eng.submit(p, sp) for p, sp in reqs]
    results = eng.run()

    assert eng.admitted == 5  # every request got a lane (3 via slot reuse)
    total = sum(3 + 2 * (r % 3) for r in range(5))
    assert eng.decode_steps < total  # batching: fewer steps than serial tokens
    for uid, (prompt, sp) in zip(uids, reqs):
        assert results[uid].tokens == _solo_tokens(comp, prompt, sp), uid
        assert results[uid].finish_reason == "length"


def test_eos_stop_and_cache_full():
    _, comp = _compressed_tree(2, 4)
    prompt = [int(t) for t in
              jax.random.randint(jax.random.PRNGKey(4), (6,), 0, CFG.vocab)]
    base = _solo_tokens(comp, prompt, SamplingParams(max_new_tokens=6))

    # eos: serving the same prompt with eos = base[2] stops at its first
    # occurrence, which is not included in the output
    eos = base[2]
    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=24)
    uid = eng.submit(prompt, SamplingParams(max_new_tokens=10, eos_id=eos))
    res = eng.run()[uid]
    assert res.finish_reason == "eos"
    assert res.tokens == base[: base.index(eos)]

    # cache_full: a 6-token prompt in a 10-slot cache leaves room for 4
    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=10)
    uid = eng.submit(prompt, SamplingParams(max_new_tokens=50))
    res = eng.run()[uid]
    assert res.finish_reason == "cache_full"
    assert len(res.tokens) == 4


def test_per_request_sampling_is_seeded_and_heterogeneous():
    """temperature>0 lanes sample reproducibly; greedy lanes stay greedy."""
    _, comp = _compressed_tree(2, 4)
    prompt = [int(t) for t in
              jax.random.randint(jax.random.PRNGKey(5), (6,), 0, CFG.vocab)]
    greedy = _solo_tokens(comp, prompt, SamplingParams(max_new_tokens=4))

    def both(seed):
        eng = DecodeEngine(MODEL, comp, max_batch=2, max_len=24, seed=seed)
        u_hot = eng.submit(
            prompt, SamplingParams(temperature=1.0, top_k=5, max_new_tokens=4)
        )
        u_cold = eng.submit(prompt, SamplingParams(max_new_tokens=4))
        res = eng.run()
        return res[u_hot].tokens, res[u_cold].tokens

    hot1, cold1 = both(seed=7)
    hot2, cold2 = both(seed=7)
    assert hot1 == hot2 and cold1 == cold2  # same seed -> same trajectory
    assert cold1 == greedy  # a hot lane does not perturb a greedy lane
    assert len(hot1) == 4


def test_stats_throughput_counts_decode_tokens_only():
    """max_new_tokens=1 finishes at admission (prefill-sampled token): no
    decode step ran, so throughput must report 0, not n/epsilon."""
    _, comp = _compressed_tree(2, 4)
    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=16)
    prompt = [int(t) for t in
              jax.random.randint(jax.random.PRNGKey(8), (6,), 0, CFG.vocab)]
    uid = eng.submit(prompt, SamplingParams(max_new_tokens=1))
    res = eng.run()[uid]
    assert len(res.tokens) == 1
    st = eng.stats()
    assert st["decode_steps"] == 0
    assert st["decode_tokens"] == 0
    assert st["tokens_per_s"] == 0.0
    assert st["tokens_generated"] == 1


def test_windowed_arch_heterogeneous_lanes_match_solo():
    """Sliding-window attention: the rolling-window shift is gated per lane,
    so continuous batching with misaligned prompt lengths must reproduce
    each request's solo tokens even once one lane's window rolls."""
    cfg = get_config("recurrentgemma-9b", smoke=True)  # local_window=16
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    comp = compress_params(recipe.export_sparse(params), recipe.sparsity)
    max_len = 20  # attn cache holds min(20, window=16): rolls at pos >= 16
    prompts = [
        [int(t) for t in jax.random.randint(jax.random.PRNGKey(9), (5,), 0, cfg.vocab)],
        [int(t) for t in jax.random.randint(jax.random.PRNGKey(10), (11,), 0, cfg.vocab)],
    ]
    sp = SamplingParams(max_new_tokens=8)  # lane 1 crosses pos 16

    solo = []
    for p in prompts:
        eng = DecodeEngine(model, comp, max_batch=1, max_len=max_len)
        uid = eng.submit(p, sp)
        solo.append(eng.run()[uid].tokens)

    eng = DecodeEngine(model, comp, max_batch=2, max_len=max_len)
    uids = [eng.submit(p, sp) for p in prompts]
    results = eng.run()
    for uid, expected in zip(uids, solo):
        assert results[uid].tokens == expected


def test_serve_launcher_has_no_decompress_in_decode_loop():
    """The acceptance-criterion tripwire: launch/serve.py must not rehydrate
    the compressed tree."""
    import inspect

    import repro.launch.serve as serve

    src = inspect.getsource(serve)
    assert "decompress_params" not in src
