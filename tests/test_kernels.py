"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per-kernel shape/dtype/N:M sweeps with assert_allclose against ref.py, plus
hypothesis property sweeps, as the deliverable requires. hypothesis is an
optional dependency: without it the fixed-case sweeps still run and the
property tests are skipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or a skip shim

from repro.kernels import ref
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels.ops import nm_mask_apply, nm_spmm

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]
NM = [(1, 4), (2, 4), (2, 8), (4, 8), (4, 16), (8, 32)]


@pytest.mark.parametrize("n,m", NM)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(64, 48), (128, 128), (512, 300), (96, 64)])
def test_nm_mask_kernel_matches_ref(n, m, dtype, shape):
    if shape[0] % m:
        pytest.skip("rows not divisible by m")
    w = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    masked, mask = nm_mask_apply_pallas(w, n, m, block_r=64, block_c=64, interpret=True)
    rmask = ref.nm_mask(w, n, m, 0)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(rmask * w))


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(1, 4), (2, 4), (2, 8)]),
    st.integers(1, 6),
    st.integers(1, 5),
    st.integers(0, 2**31 - 1),
)
def test_nm_mask_kernel_property(nm, gr, gc, seed):
    n, m = nm
    shape = (gr * m * 2, gc * 16)
    w = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    masked, mask = nm_mask_apply_pallas(w, n, m, block_r=m * 2, block_c=16, interpret=True)
    rmask = ref.nm_mask(w, n, m, 0)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))


@pytest.mark.parametrize("n,m", NM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nm_spmm_kernel_matches_ref(n, m, dtype):
    b, k, o = 16, 128, 96
    x = jax.random.normal(jax.random.PRNGKey(0), (b, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, o), dtype)
    v, i = ref.nm_compress(w, n, m, 0)
    y = nm_spmm_pallas(x, v, i, n, m, bm=8, bo=32, bk=32, interpret=True)
    yr = ref.nm_spmm_ref(x, v, i, n, m)
    atol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol, rtol=1e-2
    )


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(2, 4), (1, 4)]),
    st.integers(1, 4),
    st.integers(1, 4),
    st.integers(0, 2**31 - 1),
)
def test_nm_spmm_property(nm, bi, oi, seed):
    n, m = nm
    b, k, o = 8 * bi, 64, 16 * oi
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, o), jnp.float32)
    v, i = ref.nm_compress(w, n, m, 0)
    y = nm_spmm_pallas(x, v, i, n, m, bm=8, bo=16, bk=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.nm_spmm_ref(x, v, i, n, m)), atol=1e-4, rtol=1e-3
    )


def test_ops_wrappers_fallback_on_cpu():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    mask, masked = nm_mask_apply(w, 2, 4)  # CPU -> reference path
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref.nm_mask(w, 2, 4, 0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    v, i = ref.nm_compress(w, 2, 4, 0)
    y = nm_spmm(x, v, i, 2, 4)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.nm_spmm_ref(x, v, i, 2, 4)), atol=1e-5
    )


def test_ops_wrappers_pallas_interpret_path():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    mask, masked = nm_mask_apply(w, 2, 4, mode="interpret")
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref.nm_mask(w, 2, 4, 0)))
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(mask * w))
