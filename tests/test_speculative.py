"""Self-speculative decoding: sparse drafter, dense verifier, lossless
accept/rollback.

The load-bearing guarantees:

1. **Losslessness** — greedy token streams from a speculative engine are
   bit-identical to a plain engine serving the *verifier* tree, for any
   drafter (even a completely disagreeing one), across {slab, paged} ×
   {single-device, (2,4) mesh}.  The drafter only steers which tokens
   get proposed; every emitted distribution is the verifier's.
2. **Distributional exactness** (temperature > 0) — the rejection rule
   emits tokens whose marginal matches the verifier's filtered
   distribution exactly, and the greedy branch is the rejection rule
   specialized to one-hot distributions.
3. **Rollback conservation** — speculative page reservation + rollback
   under randomized churn (admissions, COW prefix forks, preemptions)
   never leaks a page or a refcount: ``free + used == num_pages`` at
   every step, all-zero refcounts at the end.
4. **Gating** — windowed / SSM archs and the device scheduler reject
   ``spec_gamma`` with actionable errors (their state cannot roll back /
   their sync model conflicts), and ``spec_gamma="auto"`` resolves via
   the byte-ratio roofline.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.serving.kv_pool import PagedKVPool
from repro.serving.sampling import filtered_probs, spec_accept
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

CFG = get_config("gpt2-paper", smoke=True)
MODEL = TransformerLM(CFG)


def _trees(seed=0, cfg=CFG, model=MODEL):
    params = model.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    sparse = recipe.export_sparse(params)
    return sparse, compress_params(sparse, recipe.sparsity)


def _prompts(cfg, lens, seed=100):
    return [
        [
            int(t)
            for t in jax.random.randint(
                jax.random.PRNGKey(seed + i), (n,), 0, cfg.vocab
            )
        ]
        for i, n in enumerate(lens)
    ]


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return [res[u].tokens for u in uids], [res[u].finish_reason for u in uids]


# ---------------------------------------------------------------------------
# losslessness: spec streams == plain verifier streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [dict(), dict(num_pages=48, page_size=4)],
                         ids=["slab", "paged"])
@pytest.mark.parametrize("gamma", [1, 3])
def test_greedy_parity_disagreeing_drafter(kw, gamma):
    """A drafter with *different weights* (seed-1 init) cannot change the
    greedy stream — rejected drafts roll back, every emitted token is the
    verifier's argmax.  Low acceptance just means more rounds."""
    verify, _ = _trees(seed=0)
    draft, _ = _trees(seed=1)
    prompts = _prompts(CFG, [7, 4, 9])
    sps = [SamplingParams(max_new_tokens=10)] * 3
    base = _stream(
        DecodeEngine(MODEL, verify, max_batch=3, max_len=32, donate=False,
                     **kw),
        prompts, sps,
    )
    eng = DecodeEngine(
        MODEL, draft, max_batch=3, max_len=32, spec_gamma=gamma,
        verify_params=verify, **kw,
    )
    got = _stream(eng, prompts, sps)
    assert got == base
    st = eng.stats()
    assert st["spec_rounds"] > 0
    assert st["host_syncs"] == st["spec_rounds"]
    # two weight inits rarely agree: some drafts must have been rejected
    # (and rolled back) for the parity above to be meaningful
    assert st["acceptance_rate"] < 1.0


@pytest.mark.parametrize("kw", [dict(), dict(num_pages=48, page_size=4)],
                         ids=["slab", "paged"])
def test_greedy_parity_self_drafter(kw):
    """drafter == verifier: acceptance is 1.0 by construction and each
    round commits gamma+1 tokens (modulo budget truncation)."""
    verify, comp = _trees(seed=0)
    prompts = _prompts(CFG, [6, 3])
    sps = [SamplingParams(max_new_tokens=12)] * 2
    base = _stream(
        DecodeEngine(MODEL, verify, max_batch=2, max_len=32, donate=False,
                     **kw),
        prompts, sps,
    )
    eng = DecodeEngine(
        MODEL, verify, max_batch=2, max_len=32, spec_gamma=4,
        verify_params=verify, **kw,
    )
    got = _stream(eng, prompts, sps)
    assert got == base
    st = eng.stats()
    assert st["acceptance_rate"] == 1.0
    assert st["accepted_per_verify"] > 1.0
    # strictly fewer host syncs than one-per-token decode
    assert st["host_syncs"] < st["spec_emitted_tokens"]


def test_parity_with_chunked_prefill_and_prefix_cache():
    """spec composes with the chunked-prefill and prefix-cache admission
    paths: both feed the engine committed verifier KV, which is exactly
    what a speculative round expects to extend."""
    verify, _ = _trees(seed=0)
    draft, _ = _trees(seed=1)
    prompts = _prompts(CFG, [9, 9], seed=40)
    prompts[1] = prompts[0][:6] + prompts[1][6:]  # shared head for the radix hit
    sps = [SamplingParams(max_new_tokens=8)] * 2
    kw = dict(num_pages=48, page_size=4, prefill_chunk=4, prefix_cache=True)
    base = _stream(
        DecodeEngine(MODEL, verify, max_batch=2, max_len=32, donate=False,
                     **kw),
        prompts, sps,
    )
    got = _stream(
        DecodeEngine(MODEL, draft, max_batch=2, max_len=32, spec_gamma=3,
                     verify_params=verify, **kw),
        prompts, sps,
    )
    assert got == base


def test_budget_edges_and_eos_mid_block():
    """gamma past the remaining budget truncates (a 1-token request goes
    straight to the verify bonus), and an EOS inside an accepted block
    drops the tail exactly like the plain engine's stop rule."""
    verify, _ = _trees(seed=0)
    prompts = _prompts(CFG, [5, 5, 5])
    # find the eos the plain engine would hit so the stop actually fires
    probe = DecodeEngine(MODEL, verify, max_batch=3, max_len=32, donate=False)
    ptoks, _ = _stream(probe, prompts,
                       [SamplingParams(max_new_tokens=8)] * 3)
    eos = ptoks[0][3]  # 4th emitted token of request 0
    sps = [
        SamplingParams(max_new_tokens=1),
        SamplingParams(max_new_tokens=8, eos_id=eos),
        SamplingParams(max_new_tokens=8),
    ]
    base = _stream(
        DecodeEngine(MODEL, verify, max_batch=3, max_len=32, donate=False),
        prompts, sps,
    )
    got = _stream(
        DecodeEngine(MODEL, verify, max_batch=3, max_len=32, spec_gamma=6,
                     verify_params=verify),
        prompts, sps,
    )
    assert got == base


# ---------------------------------------------------------------------------
# (2,4) mesh: spec executables carry their own shardings
# ---------------------------------------------------------------------------


def _mesh_trees():
    # f32 pins the streams: untrained bf16 logits have near-tie argmax
    # margins that psum reassociation can flip (see test_sharded_serving)
    cfg = dataclasses.replace(CFG, param_dtype="float32")
    model = TransformerLM(cfg)
    sparse, comp = _trees(cfg=cfg, model=model)
    return cfg, model, sparse, comp


@needs8
@pytest.mark.parametrize("kw", [dict(), dict(num_pages=48, page_size=4)],
                         ids=["slab", "paged"])
def test_mesh_greedy_parity(kw):
    """(data=2, model=4) speculative engine == plain verifier engine on
    the same mesh, compressed drafter against the masked-dense verifier
    (the two-fidelity pairing serve.py ships)."""
    from repro.launch.mesh import make_local_mesh

    cfg, model, sparse, comp = _mesh_trees()
    mesh = make_local_mesh(4, data=2)
    prompts = _prompts(cfg, [7, 4, 9])
    sps = [SamplingParams(max_new_tokens=8)] * 3
    base = _stream(
        DecodeEngine(model, sparse, max_batch=3, max_len=32, mesh=mesh,
                     donate=False, **kw),
        prompts, sps,
    )
    got = _stream(
        DecodeEngine(model, comp, max_batch=3, max_len=32, mesh=mesh,
                     spec_gamma=3, verify_params=sparse, **kw),
        prompts, sps,
    )
    assert got == base


@needs8
def test_mesh_matches_single_device():
    """The same speculative workload on the (2,4) mesh and on one device
    produces identical streams (f32 — see _mesh_trees)."""
    from repro.launch.mesh import make_local_mesh

    cfg, model, sparse, comp = _mesh_trees()
    prompts = _prompts(cfg, [6, 5])
    sps = [SamplingParams(max_new_tokens=8)] * 2
    single = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=32, spec_gamma=2,
                     verify_params=sparse, donate=False),
        prompts, sps,
    )
    meshed = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=32, spec_gamma=2,
                     verify_params=sparse, mesh=make_local_mesh(4, data=2)),
        prompts, sps,
    )
    assert meshed == single


# ---------------------------------------------------------------------------
# the rejection rule: distributionally exact, greedy as a special case
# ---------------------------------------------------------------------------


def _accept_batch(p_d_row, p_v_rows, g, n_rows, seed):
    """Run spec_accept over n_rows i.i.d. rows of the same (p_draft,
    p_verify) pair; drafts are sampled from p_draft per slot."""
    v = p_d_row.shape[-1]
    key = jax.random.PRNGKey(seed)
    kd, ka, kr = jax.random.split(key, 3)
    drafts = jax.random.categorical(
        kd, jnp.log(jnp.broadcast_to(p_d_row, (n_rows, g, v)))
    )
    p_d = jnp.broadcast_to(p_d_row, (n_rows, g, v))
    p_v = jnp.broadcast_to(p_v_rows, (n_rows, g + 1, v))
    gi = jnp.full((n_rows,), g, jnp.int32)
    toks, n_acc = spec_accept(
        drafts, p_d, p_v, gi,
        jax.random.split(ka, n_rows), jax.random.split(kr, n_rows),
        need_sample=True,
    )
    return np.asarray(toks), np.asarray(n_acc)


def test_rejection_rule_marginal_is_verifier():
    """The first emitted token's empirical distribution matches p_verify
    exactly (the standard speculative-sampling correctness property),
    even though drafts come from a very different p_draft."""
    p_d = jnp.asarray([0.7, 0.1, 0.1, 0.1])
    p_v = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    n = 40000
    toks, _ = _accept_batch(p_d, jnp.stack([p_v, p_v]), 1, n, seed=0)
    emp = np.bincount(toks[:, 0], minlength=4) / n
    np.testing.assert_allclose(emp, np.asarray(p_v), atol=0.01)


def test_identical_distributions_always_accept():
    p = jnp.asarray([0.25, 0.25, 0.25, 0.25])
    _, n_acc = _accept_batch(p, jnp.stack([p, p, p]), 2, 2000, seed=1)
    assert (n_acc == 2).all()
    # and the bonus slot then samples from the verifier's own p (residual
    # with a zero draft distribution)
    toks, _ = _accept_batch(p, jnp.stack([p, p, p]), 2, 2000, seed=2)
    assert ((toks >= 0) & (toks < 4)).all()


def test_disjoint_supports_always_reject():
    p_d = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    p_v = jnp.asarray([0.0, 0.5, 0.5, 0.0])
    toks, n_acc = _accept_batch(p_d, jnp.stack([p_v, p_v]), 1, 500, seed=3)
    assert (n_acc == 0).all()
    # the correction token comes from the residual = p_verify itself
    assert set(np.unique(toks[:, 0])) <= {1, 2}


def test_greedy_is_rejection_rule_with_onehot():
    """temperature == 0 rows: filtered_probs returns one-hot argmax and
    the sampled branch reduces to longest-prefix accept — both branches
    of spec_accept agree token for token."""
    b, g, v = 8, 3, 16
    key = jax.random.PRNGKey(4)
    logits_d = jax.random.normal(key, (b, g, v))
    logits_v = jax.random.normal(jax.random.fold_in(key, 1), (b, g + 1, v))
    p_d = filtered_probs(logits_d, jnp.zeros((b, g)),
                         jnp.zeros((b, g), jnp.int32))
    p_v = filtered_probs(logits_v, jnp.zeros((b, g + 1)),
                         jnp.zeros((b, g + 1), jnp.int32))
    drafts = jnp.argmax(logits_d, -1)  # what a greedy drafter proposes
    gi = jnp.full((b,), g, jnp.int32)
    ka = jax.random.split(jax.random.PRNGKey(5), b)
    kr = jax.random.split(jax.random.PRNGKey(6), b)
    t_greedy, n_greedy = spec_accept(drafts, p_d, p_v, gi, ka, kr,
                                     need_sample=False)
    t_samp, n_samp = spec_accept(drafts, p_d, p_v, gi, ka, kr,
                                 need_sample=True)
    np.testing.assert_array_equal(np.asarray(n_greedy), np.asarray(n_samp))
    np.testing.assert_array_equal(np.asarray(t_greedy), np.asarray(t_samp))


def test_per_lane_draft_lengths():
    """gi varies per row: slots past a row's gi are ignored no matter
    what garbage they hold."""
    b, g, v = 3, 4, 8
    p = jnp.full((b, g, v), 1.0 / v)
    p_v = jnp.full((b, g + 1, v), 1.0 / v)
    drafts = jnp.zeros((b, g), jnp.int32)
    gi = jnp.asarray([0, 2, 4], jnp.int32)
    ka = jax.random.split(jax.random.PRNGKey(7), b)
    kr = jax.random.split(jax.random.PRNGKey(8), b)
    toks, n_acc = spec_accept(drafts, p, p_v, gi, ka, kr, need_sample=True)
    assert (np.asarray(n_acc) <= np.asarray(gi)).all()
    assert int(n_acc[0]) == 0  # nothing proposed, only the bonus


def test_sampled_engine_run():
    """End-to-end sampled run: drafter == verifier accepts every proposal
    (the min(1, p_v/p_d) ratio is 1), requests finish on budget, and
    mixed greedy/sampled batches coexist.  Sampled streams are exact in
    *distribution*, not bitwise — accepted draws consume the drafter's
    fold_in RNG stream, so only the rejection-rule unit tests (above) and
    the greedy parity tests lock token-level behavior."""
    verify, _ = _trees(seed=0)
    prompts = _prompts(CFG, [6, 4])
    sps = [
        SamplingParams(max_new_tokens=10, temperature=0.9, top_k=16),
        SamplingParams(max_new_tokens=10),  # greedy rides in the same batch
    ]
    greedy_base = _stream(
        DecodeEngine(MODEL, verify, max_batch=2, max_len=32, seed=11,
                     donate=False),
        prompts, [SamplingParams(max_new_tokens=10)] * 2,
    )
    eng = DecodeEngine(MODEL, verify, max_batch=2, max_len=32, seed=11,
                       spec_gamma=3, verify_params=verify)
    (toks, reasons) = _stream(eng, prompts, sps)
    assert eng.stats()["acceptance_rate"] == 1.0
    assert [len(t) for t in toks] == [10, 10]
    assert reasons == ["length", "length"]
    assert all(0 <= t < CFG.vocab for t in toks[0])
    # the greedy lane is unaffected by its sampled neighbor
    assert toks[1] == greedy_base[0][1]


# ---------------------------------------------------------------------------
# rollback: page-conservation under speculative churn
# ---------------------------------------------------------------------------


def _check_conserved(pool):
    assert pool.free_pages + pool.used_pages == pool.layout.num_pages
    assert pool.used_pages == int((pool._ref > 0).sum())
    for lane_map in pool._full_pages:
        for pid in lane_map.values():
            assert pool._ref[pid] > 0, f"mapped page {pid} has no reference"


def test_rollback_conservation_random_churn():
    """400 random ops — admissions (some forking a live lane's prefix),
    speculative reservations (``ensure_steps`` over a gamma+1 horizon)
    followed by *partial rollback* to a random accepted length, COW
    drains, preemptions — never break ``free + used == num_pages``; at
    the end every refcount is zero."""
    pool = PagedKVPool(MODEL, max_batch=4, max_len=32, num_pages=24,
                       page_size=4)
    rng = random.Random(11)
    gamma = 6
    lens: dict[int, int] = {}  # lane -> committed length

    for _ in range(400):
        op = rng.random()
        idle = [l for l in range(pool.max_batch) if l not in lens]
        live = sorted(lens)
        if op < 0.35 and idle:
            lane = rng.choice(idle)
            plen = rng.randint(2, 16)
            shared, shared_len = (), 0
            donors = [l for l in live if lens[l] >= 2]
            if donors and rng.random() < 0.5:
                d = rng.choice(donors)
                shared_len = rng.randint(1, min(lens[d], plen) - 1)
                full, tail = pool.prompt_pages(d, shared_len)
                shared = tuple(full + ([tail] if tail is not None else []))
            if pool.alloc_prefill(lane, plen, shared_full=shared,
                                  shared_len=shared_len):
                lens[lane] = plen
        elif op < 0.80 and live:
            # one speculative round: reserve the full horizon, then
            # commit a random prefix (0..gamma accepted drafts + bonus)
            lane = rng.choice(live)
            horizon = min(gamma + 1, pool.max_len - lens[lane])
            if horizon < 1 or not pool.ensure_steps(lane, lens[lane],
                                                    horizon):
                pool.release(lane)
                del lens[lane]
            else:
                accepted = rng.randint(1, horizon)
                lens[lane] += accepted
                pool.rollback(lane, lens[lane])
        elif op < 0.9 and live:
            lane = rng.choice(live)
            pool.release(lane)
            del lens[lane]
        elif pool.pending_copies:
            pool.cache = pool.apply_pending(pool.cache)
            assert not pool.pending_copies
        _check_conserved(pool)

    for lane in list(lens):
        pool.release(lane)
    pool.cache = pool.apply_pending(pool.cache)
    assert pool.free_pages == pool.layout.num_pages
    assert pool.used_pages == 0
    assert (pool._ref == 0).all()


def test_rollback_keeps_shared_prefix_pages():
    """Rolling a fork back through shared territory decrefs — the donor's
    prefix pages must survive with their own reference intact."""
    pool = PagedKVPool(MODEL, max_batch=2, max_len=32, num_pages=16,
                       page_size=4)
    assert pool.alloc_prefill(0, 12)  # 3 full pages
    full, _ = pool.prompt_pages(0, 12)
    assert pool.alloc_prefill(1, 13, shared_full=tuple(full), shared_len=12)
    assert all(pool._ref[p] == 2 for p in full)
    # lane 1 speculates past the shared prefix, then rejects everything
    assert pool.ensure_steps(1, 13, 7)
    pool.rollback(1, 14)
    _check_conserved(pool)
    # shared pages keep the donor's ref; only lane 1's over-reservation
    # came back
    assert all(pool._ref[p] >= 1 for p in full)
    pool.release(0)
    pool.release(1)
    pool.cache = pool.apply_pending(pool.cache)
    assert (pool._ref == 0).all()


def test_rollback_keeps_next_write_page():
    """The page holding position new_len stays mapped (the next decode
    token writes there), pages strictly past it free."""
    pool = PagedKVPool(MODEL, max_batch=1, max_len=32, num_pages=16,
                       page_size=4)
    assert pool.alloc_prefill(0, 4)
    assert pool.ensure_steps(0, 4, 8)  # pages for positions 4..11
    used_before = pool.used_pages
    pool.rollback(0, 5)  # keep page 1 (position 5 writes page 1)
    assert pool.used_pages < used_before
    assert 1 in pool._full_pages[0]
    assert 2 not in pool._full_pages[0]
    _check_conserved(pool)


# ---------------------------------------------------------------------------
# gating + gamma selection
# ---------------------------------------------------------------------------


def test_gating_errors():
    verify, comp = _trees(seed=0)
    with pytest.raises(ValueError, match="verify_params"):
        DecodeEngine(MODEL, comp, max_batch=1, max_len=16, spec_gamma=2)
    with pytest.raises(ValueError, match="sync scheduler"):
        DecodeEngine(MODEL, comp, max_batch=1, max_len=16, spec_gamma=2,
                     verify_params=verify, max_steps_per_dispatch=4)
    with pytest.raises(ValueError, match=">= 1"):
        DecodeEngine(MODEL, comp, max_batch=1, max_len=16, spec_gamma=0,
                     verify_params=verify)
    with pytest.raises(ValueError, match="max_len"):
        DecodeEngine(MODEL, comp, max_batch=1, max_len=16, spec_gamma=16,
                     verify_params=verify)


def test_gating_windowed_arch():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    model = TransformerLM(cfg)
    sparse, comp = _trees(cfg=cfg, model=model)
    with pytest.raises(ValueError, match="window"):
        DecodeEngine(model, comp, max_batch=1, max_len=16, spec_gamma=2,
                     verify_params=sparse)


def test_gating_ssm_arch():
    cfg = get_config("mamba2-2.7b", smoke=True)
    model = TransformerLM(cfg)
    sparse, comp = _trees(cfg=cfg, model=model)
    with pytest.raises(ValueError, match="SSM"):
        DecodeEngine(model, comp, max_batch=1, max_len=16, spec_gamma=2,
                     verify_params=sparse)


def test_pick_spec_gamma_roofline():
    # cheaper drafter -> longer drafts pay off
    cheap = DecodeEngine.pick_spec_gamma(10, 1000)
    parity = DecodeEngine.pick_spec_gamma(1000, 1000)
    assert cheap > parity >= 1
    # a worthless drafter (alpha ~ 0) never drafts more than the minimum
    assert DecodeEngine.pick_spec_gamma(500, 1000, alpha=0.01) == 1


def test_spec_gamma_auto_resolves():
    verify, comp = _trees(seed=0)
    eng = DecodeEngine(MODEL, comp, max_batch=1, max_len=32,
                       spec_gamma="auto", verify_params=verify)
    assert 1 <= eng.spec_gamma < 32
    st_keys = {"spec_gamma", "acceptance_rate", "accepted_per_verify",
               "draft_tokens", "verify_tokens", "bytes_per_accepted_token",
               "spec_per_request"}
    assert st_keys <= set(eng.stats())
