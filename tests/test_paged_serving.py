"""Paged KV-cache pool + bucketed batched prefill.

Load-bearing guarantees of the paged serving stack:

1. **Parity** — paged decode is bit-identical to slab decode on the same
   request stream (same tokens, same finish reasons) across full
   attention, MLA, and sliding-window archs, for greedy and sampled lanes.
2. **Preemption replaces truncation** — under a deliberately undersized
   pool, requests are preempted, re-queued with their generated prefix,
   and resumed to the *same* greedy tokens; nothing finishes
   ``cache_full`` from pool pressure.
3. **Scheduling** — block-granular admission lets the paged engine run
   strictly more concurrent requests than a slab of equal HBM budget on
   heterogeneous prompt lengths.
4. ``sample_tokens`` row isolation and the static all-greedy path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, PagedKVPool, SamplingParams
from repro.serving.sampling import sample_tokens
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")


def _compressed(arch: str, seed=0):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    return cfg, model, compress_params(recipe.export_sparse(params), recipe.sparsity)


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return (
        [res[u].tokens for u in uids],
        [res[u].finish_reason for u in uids],
    )


def _rand_prompt(seed, n, vocab):
    return [int(t) for t in jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab)]


# ---------------------------------------------------------------------------
# parity: paged decode ≡ slab decode on the same request stream
# ---------------------------------------------------------------------------


def test_paged_parity_attn_greedy_and_sampled():
    """gpt2 (full attention): 4 heterogeneous requests over 2 lanes, one
    sampled lane — the paged engine reproduces the slab engine exactly."""
    cfg, model, comp = _compressed("gpt2-paper")
    prompts = [_rand_prompt(100 + r, 3 + 3 * r, cfg.vocab) for r in range(4)]
    sps = [SamplingParams(max_new_tokens=4 + r) for r in range(4)]
    sps[2] = SamplingParams(temperature=1.0, top_k=5, max_new_tokens=5)

    slab = DecodeEngine(model, comp, max_batch=2, max_len=32, seed=3)
    t_slab, r_slab = _stream(slab, prompts, sps)
    paged = DecodeEngine(
        model, comp, max_batch=2, max_len=32, seed=3, num_pages=16, page_size=8
    )
    t_paged, r_paged = _stream(paged, prompts, sps)
    assert t_paged == t_slab
    assert r_paged == r_slab
    assert paged.layout.kind == "paged" and slab.layout.kind == "slab"


def test_paged_parity_mla():
    """DeepSeek MLA: the latent (ckv, krope) cache pages like attention."""
    cfg, model, comp = _compressed("deepseek-v2-lite-16b")
    prompts = [_rand_prompt(9, 5, cfg.vocab), _rand_prompt(10, 11, cfg.vocab)]
    sps = [SamplingParams(max_new_tokens=6)] * 2
    slab = DecodeEngine(model, comp, max_batch=2, max_len=24, seed=0)
    t_slab, _ = _stream(slab, prompts, sps)
    paged = DecodeEngine(
        model, comp, max_batch=2, max_len=24, seed=0, num_pages=24, page_size=4
    )
    t_paged, _ = _stream(paged, prompts, sps)
    assert t_paged == t_slab


def test_windowed_decode_past_boundary_heterogeneous_and_paged():
    """Sliding window (RecurrentGemma, window=16): misaligned lanes decode
    well past the window boundary.  Locks in the per-lane rolling-window
    gating (batched == solo) and the paged modular table (paged == slab,
    with whole expired pages actually evicted back to the free list)."""
    cfg, model, comp = _compressed("recurrentgemma-9b")
    max_len = 40  # > window: both lanes roll; lane 1 crosses pos 16 mid-run
    prompts = [_rand_prompt(9, 5, cfg.vocab), _rand_prompt(10, 11, cfg.vocab)]
    sps = [SamplingParams(max_new_tokens=20)] * 2  # ends at pos 25 / 31

    solo = []
    for p, sp in zip(prompts, sps):
        eng = DecodeEngine(model, comp, max_batch=1, max_len=max_len)
        solo.append(_stream(eng, [p], [sp])[0][0])

    slab = DecodeEngine(model, comp, max_batch=2, max_len=max_len)
    t_slab, _ = _stream(slab, prompts, sps)
    assert t_slab == solo  # per-lane window gating at and past the boundary

    paged = DecodeEngine(
        model, comp, max_batch=2, max_len=max_len, num_pages=32, page_size=4
    )
    t_paged, _ = _stream(paged, prompts, sps)
    assert t_paged == solo
    # the window slid past whole pages: they went back to the free list
    assert paged.pool.evicted_pages > 0


# ---------------------------------------------------------------------------
# preemption-and-resume replaces cache_full truncation
# ---------------------------------------------------------------------------


def test_preemption_resume_matches_unpreempted_greedy():
    """A pool too small for two full requests preempts the youngest lane
    and resumes it from its prompt + generated prefix: same greedy tokens
    as the un-preempted slab run, and no pool-pressure cache_full."""
    cfg, model, comp = _compressed("gpt2-paper")
    prompts = [_rand_prompt(100 + r, 5, cfg.vocab) for r in range(2)]
    sps = [SamplingParams(max_new_tokens=8)] * 2

    ref = DecodeEngine(model, comp, max_batch=2, max_len=16, seed=0)
    t_ref, r_ref = _stream(ref, prompts, sps)

    # each request grows to 13 tokens = 7 pages of 2; 8 total forces a preempt
    eng = DecodeEngine(
        model, comp, max_batch=2, max_len=16, seed=0, num_pages=8, page_size=2
    )
    t, r = _stream(eng, prompts, sps)
    assert eng.preemptions > 0
    assert t == t_ref
    assert r == r_ref and all(x == "length" for x in r)


def test_submit_rejects_request_larger_than_whole_pool():
    _, model, comp = _compressed("gpt2-paper")
    eng = DecodeEngine(
        model, comp, max_batch=2, max_len=32, num_pages=3, page_size=2
    )
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(1, 11)), SamplingParams(max_new_tokens=20))


# ---------------------------------------------------------------------------
# scheduling: block granularity buys concurrency at equal HBM budget
# ---------------------------------------------------------------------------


def test_paged_admits_more_concurrency_at_equal_budget():
    cfg, model, comp = _compressed("gpt2-paper")
    max_len, page_size, slab_batch = 32, 8, 2
    budget_tokens = slab_batch * max_len
    prompts = [_rand_prompt(500 + r, 4 + (r * 5) % 12, cfg.vocab) for r in range(8)]
    sps = [SamplingParams(max_new_tokens=6)] * len(prompts)

    slab = DecodeEngine(model, comp, max_batch=slab_batch, max_len=max_len)
    _stream(slab, prompts, sps)
    paged = DecodeEngine(
        model, comp, max_batch=4 * slab_batch, max_len=max_len,
        num_pages=budget_tokens // page_size, page_size=page_size,
    )
    _stream(paged, prompts, sps)
    assert paged.kv_cache_bytes() <= slab.kv_cache_bytes()  # equal HBM budget
    assert paged.max_concurrency > slab.max_concurrency


def test_bucketed_prefill_batches_one_group_per_bucket():
    """4 distinct prompt lengths in one bucket = one jitted prefill call
    (the per-prompt-length retrace/dispatch is gone)."""
    cfg, model, comp = _compressed("gpt2-paper")
    eng = DecodeEngine(
        model, comp, max_batch=4, max_len=32, prefill_buckets=(8, 16)
    )
    assert eng._bucket(3) == 8 and eng._bucket(9) == 16
    prompts = [_rand_prompt(40 + r, 3 + r, cfg.vocab) for r in range(4)]  # 3..6
    sps = [SamplingParams(max_new_tokens=2)] * 4
    t, _ = _stream(eng, prompts, sps)
    assert eng.prefill_batches == 1
    assert all(len(x) == 2 for x in t)


# ---------------------------------------------------------------------------
# PagedKVPool accounting
# ---------------------------------------------------------------------------


def test_kv_pool_alloc_ensure_release_accounting():
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=2, max_len=16, num_pages=10, page_size=2)
    assert pool.free_pages == 10
    assert pool.alloc_prefill(0, 5)  # positions 0..4 -> pages 0..2
    assert pool.used_pages == 3
    assert pool.ensure_steps(0, 5)  # page 2 already mapped
    assert pool.used_pages == 3
    assert pool.ensure_steps(0, 6)  # crosses into page 3
    assert pool.used_pages == 4
    assert pool.alloc_prefill(1, 5)
    assert pool.used_pages == 7
    pool.release(0)
    assert pool.used_pages == 3 and pool.free_pages == 7
    pool.release(1)
    assert pool.used_pages == 0 and pool.free_pages == 10
    # tables are sentinel-clean after release
    assert (pool.device_tables()["full"] >= pool.layout.num_pages).all()


def test_kv_pool_window_eviction_frees_whole_pages():
    _, model, _ = _compressed("recurrentgemma-9b")  # smoke window = 16
    pool = PagedKVPool(model, max_batch=1, max_len=40, num_pages=16, page_size=4)
    assert pool.layout.win == 16 and not pool.layout.has_full
    assert pool.alloc_prefill(0, 10)  # window pages 0..2
    assert pool.used_pages == 3
    before = pool.used_pages
    for pos in range(10, 30):
        assert pool.ensure_steps(0, pos)
    # live window spans <= pages_win pages; everything older was evicted
    assert pool.used_pages <= pool.layout.pages_win
    assert pool.evicted_pages > 0
    assert pool.used_pages <= before + pool.layout.pages_win


# ---------------------------------------------------------------------------
# sample_tokens: row isolation + the static all-greedy path
# ---------------------------------------------------------------------------


def test_sample_tokens_topk_zero_rows_unaffected_by_filtering_rows():
    """A top_k=0 row must sample identically whether or not *other* rows
    in the batch filter by top-k."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(jax.random.PRNGKey(1), (2, 32))
    temps = jnp.asarray([1.0, 1.0], jnp.float32)
    mixed = sample_tokens(
        logits, temps, jnp.asarray([0, 2], jnp.int32), key,
        need_sample=True, need_topk=True,
    )
    unfiltered = sample_tokens(
        logits, temps, jnp.asarray([0, 0], jnp.int32), key,
        need_sample=True, need_topk=False,
    )
    assert int(mixed[0]) == int(unfiltered[0])
    # the filtering row respects its own cutoff: one of its top-2 logits
    top2 = set(np.argsort(np.asarray(logits[1]))[-2:].tolist())
    assert int(mixed[1]) in top2

    # a greedy row (temperature 0) is exact argmax even when a sibling
    # row filters
    greedy_mix = sample_tokens(
        logits, jnp.asarray([0.0, 1.0], jnp.float32),
        jnp.asarray([0, 2], jnp.int32), key,
        need_sample=True, need_topk=True,
    )
    assert int(greedy_mix[0]) == int(jnp.argmax(logits[0]))


def test_sample_tokens_static_all_greedy_path_is_argmax():
    """need_sample=False (the compiled all-greedy fast path) must equal
    exact argmax — and agree with the dynamic path at temperature 0."""
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    key = jax.random.PRNGKey(3)
    zeros_f = jnp.zeros((4,), jnp.float32)
    zeros_i = jnp.zeros((4,), jnp.int32)
    static = sample_tokens(
        logits, zeros_f, zeros_i, key, need_sample=False, need_topk=False
    )
    np.testing.assert_array_equal(
        np.asarray(static), np.asarray(jnp.argmax(logits, axis=-1))
    )
    dynamic = sample_tokens(
        logits, zeros_f, zeros_i, key, need_sample=True, need_topk=True
    )
    np.testing.assert_array_equal(np.asarray(static), np.asarray(dynamic))
