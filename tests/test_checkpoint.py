"""Checkpoint substrate: atomicity, integrity, retention, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    Checkpointer,
    load_pytree,
    save_pytree,
    verify,
)

jax.config.update("jax_platform_name", "cpu")


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)},
    }


def test_roundtrip_preserves_values_and_dtypes(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t, {"step": 7})
    out, meta = load_pytree(str(tmp_path / "ck"), t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_corruption_detected(tmp_path):
    p = str(tmp_path / "ck")
    save_pytree(p, _tree())
    assert verify(p)
    # flip bytes in the arrays file
    f = os.path.join(p, "arrays.npz")
    data = bytearray(open(f, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(f, "wb").write(bytes(data))
    assert not verify(p)


def test_latest_step_skips_corrupt(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    ck.save(1, _tree())
    ck.save(2, _tree())
    # corrupt step 2
    f = os.path.join(str(tmp_path), "step_0000000002", "manifest.json")
    with open(f, "w") as fh:
        json.dump({"keys": [], "checksums": {}, "meta": {}}, fh)
    assert ck.latest_step() == 1
    out, meta = ck.load(_tree())
    assert meta["step"] == 1


def test_keep_last_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    assert ck.steps() == [3, 4]


def test_keep_every_archival(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=1, keep_every=2)
    for s in (1, 2, 3, 4, 5):
        ck.save(s, _tree())
    assert ck.steps() == [2, 4, 5]


def test_elastic_restore_onto_sharding(tmp_path):
    """Restore re-shards onto whatever devices the relaunch has (1 CPU here,
    via an explicit SingleDeviceSharding — the mechanism is identical for a
    256-chip NamedSharding)."""
    from jax.sharding import SingleDeviceSharding

    t = _tree()
    save_pytree(str(tmp_path / "ck"), t)
    sh = SingleDeviceSharding(jax.devices()[0])
    out, _ = load_pytree(str(tmp_path / "ck"), t, shardings=sh)
    for leaf in jax.tree_util.tree_leaves(out):
        assert leaf.sharding == sh


def test_atomic_no_partial_on_failure(tmp_path, monkeypatch):
    p = str(tmp_path / "ck")
    save_pytree(p, _tree(), {"step": 1})

    # make the next save explode mid-write; the old checkpoint must survive
    import numpy as _np

    orig = _np.savez

    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(_np, "savez", boom)
    with pytest.raises(RuntimeError):
        save_pytree(p, _tree(), {"step": 2})
    monkeypatch.setattr(_np, "savez", orig)
    assert verify(p)
    _, meta = load_pytree(p, _tree())
    assert meta["step"] == 1


def test_restore_latest_public_api(tmp_path):
    """restore_latest: newest verified step, subtree skeletons, None when
    empty — the serve launcher's restore path, no private-API reach-in."""
    ck = Checkpointer(str(tmp_path / "run"))
    assert ck.restore_latest(_tree()) is None

    full = {"params": _tree(), "opt": {"m": jnp.ones((3,))}}
    ck.save(3, full, {"note": "a"})
    ck.save(9, full)
    # a {"params": ...} skeleton reads just the parameter subtree
    restored = ck.restore_latest({"params": _tree()})
    assert restored is not None
    tree, meta, step = restored
    assert step == 9 and meta["step"] == 9
    for a, b in zip(
        jax.tree_util.tree_leaves(full["params"]),
        jax.tree_util.tree_leaves(tree["params"]),
    ):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
