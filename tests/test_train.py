"""Trainer: fault tolerance (kill/resume exactness), compression, data state."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.checkpoint import Checkpointer
from repro.data import DataIterator, SyntheticTask
from repro.train import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

TASK = SyntheticTask(seed=11, heavy_tail=False)
SCFG = core.StepConfig(
    learning_rate=2e-3,
    b2=0.99,
    autoswitch=core.AutoSwitchConfig(eps=1e-4, window=20, t_min=10, t_max=60),
)


def _loss(p, batch):
    x, y = batch
    l = TASK.loss(p, x, y)
    return l, {"mse": l}


def _make_trainer(tmpdir, total, ckpt_every=20, **kw):
    recipe = core.make_recipe("step", core.SparsityConfig(default=core.NMSparsity(2, 4)))
    data = DataIterator(batch_fn=lambda s, bs: TASK.batch(s, bs), batch_size=32, prefetch=0)
    return Trainer(
        _loss,
        recipe,
        SCFG,
        data,
        TrainerConfig(total_steps=total, log_every=0, ckpt_every=ckpt_every, **kw),
        checkpointer=Checkpointer(str(tmpdir), keep_last=3) if tmpdir else None,
    )


def test_loss_decreases_and_switches(tmp_path):
    tr = _make_trainer(None, 120)
    params = TASK.student_init(jax.random.PRNGKey(0))
    state, _ = tr.run(params)
    assert bool(state.opt.phase2)
    x, y = TASK.batch(10_000, 256)
    final = float(TASK.loss(tr.recipe.export_sparse(state.params), x, y))
    initial = float(TASK.loss(params, x, y))
    assert final < initial * 0.3


def test_kill_and_resume_is_exact(tmp_path):
    """A restart from checkpoint must reproduce the uninterrupted run bit-for-
    bit (same data stream, same optimizer state, same phase flags)."""
    params = TASK.student_init(jax.random.PRNGKey(1))
    # uninterrupted run to 60
    tr_full = _make_trainer(tmp_path / "a", 60, ckpt_every=25)
    s_full, _ = tr_full.run(params)
    # interrupted: run to 50 (checkpoint lands at 50), then "crash"; resume to 60
    tr1 = _make_trainer(tmp_path / "b", 50, ckpt_every=25)
    tr1.run(params)
    tr2 = _make_trainer(tmp_path / "b", 60, ckpt_every=25)
    s_resumed, _ = tr2.run(params)
    np.testing.assert_allclose(
        np.asarray(s_full.params["fc1"]["w"]),
        np.asarray(s_resumed.params["fc1"]["w"]),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(s_full.opt.v["fc1"]["w"]),
        np.asarray(s_resumed.opt.v["fc1"]["w"]),
        rtol=1e-6,
    )
    assert int(s_full.opt.t0) == int(s_resumed.opt.t0)


def test_resume_restores_data_stream(tmp_path):
    tr1 = _make_trainer(tmp_path, 30, ckpt_every=10)
    params = TASK.student_init(jax.random.PRNGKey(2))
    tr1.run(params)
    tr2 = _make_trainer(tmp_path, 40, ckpt_every=10)
    state, start = tr2.restore_or_init(params)
    assert start == 30
    assert tr2.data.state.step == 30


def test_ef_compression_activates_in_phase2_only():
    recipe = core.make_recipe("step", core.SparsityConfig(default=core.NMSparsity(2, 4)))
    data = DataIterator(batch_fn=lambda s, bs: TASK.batch(s, bs), batch_size=32, prefetch=0)
    tr = Trainer(
        _loss, recipe, SCFG, data,
        TrainerConfig(total_steps=80, log_every=0, ckpt_every=0, compress_phase2=True),
    )
    params = TASK.student_init(jax.random.PRNGKey(3))
    state, _ = tr.run(params)
    assert state.comp is not None
    res = np.asarray(state.comp.residual["fc1"]["w"])
    if bool(state.opt.phase2):
        assert np.abs(res).sum() > 0  # error feedback engaged
    # training still converged reasonably
    x, y = TASK.batch(10_001, 256)
    assert float(TASK.loss(state.params, x, y)) < 1.0


def test_straggler_deadline_flag():
    tr = _make_trainer(None, 3)
    tr.cfg = dataclasses.replace(tr.cfg, log_every=1)
    params = TASK.student_init(jax.random.PRNGKey(4))
    _, hist = tr.run(params, step_timeout=1e-9)  # everything is a straggler
    assert any(m.get("straggler") for m in hist)
