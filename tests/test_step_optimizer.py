"""STEP optimizer (Algorithm 1): phase mechanics and Adam equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autoswitch import AutoSwitchConfig
from repro.core.step_optimizer import StepConfig, step_optimizer
from repro.optim.adam import adam
from repro.optim.base import apply_updates

jax.config.update("jax_platform_name", "cpu")


def _params():
    return {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4) / 10.0}


def _grads(t):
    key = jax.random.PRNGKey(t)
    return {"w": jax.random.normal(key, (2, 4))}


def test_phase1_matches_plain_adam():
    """Before the switch STEP must be bit-identical to Adam (Alg.1 l.2-9)."""
    cfg = StepConfig(learning_rate=1e-2, b2=0.9, switch_at=10_000)
    sopt = step_optimizer(cfg)
    aopt = adam(1e-2, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps)
    p1, p2 = _params(), _params()
    s1, s2 = sopt.init(p1), aopt.init(p2)
    for t in range(20):
        g = _grads(t)
        u1, s1 = sopt.update(g, s1, p1)
        u2, s2 = aopt.update(g, s2, p2)
        p1 = apply_updates(p1, u1)
        p2 = apply_updates(p2, u2)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-6)
    assert not bool(s1.phase2)


def test_variance_freezes_at_switch():
    cfg = StepConfig(learning_rate=1e-2, b2=0.9, switch_at=5)
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)
    v_at_switch = None
    for t in range(12):
        g = _grads(t)
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
        if int(s.step) == 5:
            v_at_switch = np.asarray(s.v["w"]).copy()
    assert bool(s.phase2)
    assert int(s.t0) == 5
    np.testing.assert_array_equal(np.asarray(s.v["w"]), v_at_switch)


def test_precondition_is_bias_corrected_sqrt():
    cfg = StepConfig(learning_rate=1e-2, b2=0.9, eps=1e-8, switch_at=4)
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)
    for t in range(6):
        u, s = opt.update(_grads(t), s, p)
        p = apply_updates(p, u)
    bc2 = 1 - cfg.b2 ** 4
    expected = np.sqrt(np.asarray(s.v["w"]) / bc2) + cfg.eps
    np.testing.assert_allclose(np.asarray(s.precond["w"]), expected, rtol=1e-6)


def test_phase2_update_uses_frozen_preconditioner():
    cfg = StepConfig(learning_rate=0.1, b1=0.0, b2=0.9, switch_at=3)
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)
    for t in range(3):
        u, s = opt.update(_grads(t), s, p)
        p = apply_updates(p, u)
    assert bool(s.phase2)
    g = {"w": jnp.ones((2, 4))}
    u, s2 = opt.update(g, s, p)
    # with b1=0: update = -lr * g / precond (bias correction of m is 1-0^t=1)
    expected = -0.1 * 1.0 / np.asarray(s.precond["w"])
    np.testing.assert_allclose(np.asarray(u["w"]), expected, rtol=1e-5)


def test_ablation_update_v_in_phase2():
    cfg = StepConfig(learning_rate=1e-2, b2=0.9, switch_at=3, update_v_in_phase2=True)
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)
    v_prev = None
    for t in range(8):
        u, s = opt.update(_grads(t), s, p)
        p = apply_updates(p, u)
        if int(s.step) == 6:
            v_prev = np.asarray(s.v["w"]).copy()
    assert bool(s.phase2)
    assert not np.allclose(np.asarray(s.v["w"]), v_prev)  # v keeps moving


def test_autoswitch_drives_phase_change():
    # decaying gradients -> variance change shrinks below eps -> switch
    cfg = StepConfig(
        learning_rate=1e-3,
        b2=0.9,
        autoswitch=AutoSwitchConfig(eps=1e-6, window=5),
    )
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)
    for t in range(200):
        g = {"w": jnp.full((2, 4), 0.5 ** t)}  # rapidly vanishing gradients
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
        if bool(s.phase2):
            break
    assert bool(s.phase2)
    assert 5 <= int(s.t0) <= 200


def test_state_is_jit_and_scan_compatible():
    cfg = StepConfig(learning_rate=1e-2, b2=0.9, switch_at=4)
    opt = step_optimizer(cfg)
    p = _params()
    s = opt.init(p)

    @jax.jit
    def step(carry, g):
        p, s = carry
        u, s = opt.update(g, s, p)
        return (apply_updates(p, u), s), s.phase2

    gs = {"w": jax.random.normal(jax.random.PRNGKey(0), (10, 2, 4))}
    (p2, s2), phases = jax.lax.scan(step, (p, s), gs)
    assert bool(phases[-1]) and not bool(phases[0])
