"""Sharding rules + sparse-infer export + hlo cost walker units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import param_pspec
from repro.sparse_infer import compress_params, decompress_params, compression_report
from repro.core import SparsityConfig, NMSparsity
from repro.utils.hlo_cost import analyze

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize(
    "name,ndim,expected",
    [
        ("embed/tok_embed", 2, P("model", "data")),
        ("unembed/out_embed", 2, P("data", "model")),
        ("body/sb_0/attn/wq", 3, P(None, "data", "model")),
        ("body/sb_0/attn/wo", 3, P(None, "model", "data")),
        ("body/sb_0/attn/bias_q", 2, P(None, "model")),
        ("body/sb_0/mlp/w_gate", 3, P(None, "data", "model")),
        ("body/sb_0/mlp/w_down", 3, P(None, "model", "data")),
        ("body/sb_0/moe/w_gate_e", 4, P(None, "model", None, "data")),
        ("body/sb_0/moe/w_down_e", 4, P(None, "model", "data", None)),
        ("body/sb_0/moe/router", 3, P(None, None, None)),
        ("body/sb_0/mixer/w_in", 3, P(None, "data", "model")),
        ("body/sb_0/mixer/w_out", 3, P(None, "model", "data")),
        ("body/sb_0/pre/norm_scale", 2, P(None, None)),
        ("head_0/attn/wq", 2, P("data", "model")),
        ("final/norm_scale", 1, P(None)),
    ],
)
def test_param_pspec_rules(name, ndim, expected):
    assert param_pspec(name, ndim) == expected


def test_param_pspec_no_fsdp():
    assert param_pspec("head_0/attn/wq", 2, fsdp=False) == P(None, "model")


def test_state_pspecs_mirror_params():
    from repro.distributed.sharding import state_pspecs

    state_like = {
        "params": {"blk": {"attn": {"wq": jnp.zeros((4, 4))}}},
        "opt": {"m": {"blk": {"attn": {"wq": jnp.zeros((4, 4))}}}, "step": jnp.zeros(())},
    }
    specs = state_pspecs(None, state_like)
    assert specs["params"]["blk"]["attn"]["wq"] == P("data", "model")
    assert specs["opt"]["m"]["blk"]["attn"]["wq"] == P("data", "model")
    assert specs["opt"]["step"] == P()


def test_compress_decompress_roundtrip():
    cfg = SparsityConfig(default=NMSparsity(2, 4))
    params = {"blk": {"w_gate": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}}
    # make it exactly 2:4 first (a trained-and-exported model)
    from repro.core.masking import nm_mask

    params = jax.tree_util.tree_map(lambda w: w * nm_mask(w, 2, 4, 0), params)
    comp = compress_params(params, cfg)
    rep = compression_report(params, comp)
    assert rep["ratio"] < 0.8  # values half + uint8 indices
    back = decompress_params(comp)
    np.testing.assert_allclose(
        np.asarray(back["blk"]["w_gate"]), np.asarray(params["blk"]["w_gate"])
    )


def test_hlo_cost_walker_scan_and_collective():
    from jax.sharding import Mesh, NamedSharding

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, x).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 2 * 64**3 * 7
    assert r["unknown_trip_count_whiles"] == 0
