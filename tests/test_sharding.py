"""Sharding rules + sparse-infer export + hlo cost walker units."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import param_pspec, sanitize_spec
from repro.sparse_infer import compress_params, decompress_params, compression_report
from repro.core import SparsityConfig, NMSparsity
from repro.utils.hlo_cost import analyze

jax.config.update("jax_platform_name", "cpu")


class _StubMesh:
    """Axis names + device-grid shape are all sanitize_spec / the pspec
    rules read — lets spec-level tests exercise mesh shapes (16x16, zero
    axes, multi-pod tuples) that no CPU test runner can materialize."""

    def __init__(self, axes, shape):
        self.axis_names = tuple(axes)
        self.devices = np.zeros(shape)


MESH24 = _StubMesh(("data", "model"), (2, 4))


@pytest.mark.parametrize(
    "name,ndim,expected",
    [
        ("embed/tok_embed", 2, P("model", "data")),
        ("unembed/out_embed", 2, P("data", "model")),
        ("body/sb_0/attn/wq", 3, P(None, "data", "model")),
        ("body/sb_0/attn/wo", 3, P(None, "model", "data")),
        ("body/sb_0/attn/bias_q", 2, P(None, "model")),
        ("body/sb_0/mlp/w_gate", 3, P(None, "data", "model")),
        ("body/sb_0/mlp/w_down", 3, P(None, "model", "data")),
        ("body/sb_0/moe/w_gate_e", 4, P(None, "model", None, "data")),
        ("body/sb_0/moe/w_down_e", 4, P(None, "model", "data", None)),
        ("body/sb_0/moe/router", 3, P(None, None, None)),
        ("body/sb_0/mixer/w_in", 3, P(None, "data", "model")),
        ("body/sb_0/mixer/w_out", 3, P(None, "model", "data")),
        ("body/sb_0/pre/norm_scale", 2, P(None, None)),
        ("head_0/attn/wq", 2, P("data", "model")),
        ("final/norm_scale", 1, P(None)),
    ],
)
def test_param_pspec_rules(name, ndim, expected):
    assert param_pspec(name, ndim) == expected


def test_param_pspec_no_fsdp():
    assert param_pspec("head_0/attn/wq", 2, fsdp=False) == P(None, "model")


def test_state_pspecs_mirror_params():
    from repro.distributed.sharding import state_pspecs

    state_like = {
        "params": {"blk": {"attn": {"wq": jnp.zeros((4, 4))}}},
        "opt": {"m": {"blk": {"attn": {"wq": jnp.zeros((4, 4))}}}, "step": jnp.zeros(())},
    }
    specs = state_pspecs(None, state_like)
    assert specs["params"]["blk"]["attn"]["wq"] == P("data", "model")
    assert specs["opt"]["m"]["blk"]["attn"]["wq"] == P("data", "model")
    assert specs["opt"]["step"] == P()


@pytest.mark.parametrize(
    "spec,shape,mesh,expected",
    [
        # tuple axis entries: product of the tuple's sizes must divide
        (P(("pod", "data")), (8, 4), _StubMesh(("pod", "data", "model"), (2, 2, 4)), P(("pod", "data"), None)),
        (P(("pod", "data")), (6, 4), _StubMesh(("pod", "data", "model"), (2, 2, 4)), P(None, None)),
        # zero-size mesh axis: never shard onto it
        (P("model"), (8,), _StubMesh(("data", "model"), (2, 0)), P(None)),
        # odd vocab dims (mamba2's 50280 on a 16-way axis) degrade per-dim
        (P("model", "data"), (50280, 64), _StubMesh(("data", "model"), (16, 16)), P(None, "data")),
        # absent axis names count as size 1 (spec written for a bigger mesh)
        (P("pod", "model"), (4, 8), MESH24, P("pod", "model")),
        # rank padding: spec shorter than the shape
        (P("model"), (8, 6), MESH24, P("model", None)),
    ],
)
def test_sanitize_spec_edge_cases(spec, shape, mesh, expected):
    assert sanitize_spec(spec, shape, mesh) == expected


def _ct(name, dense_shape, n=2, m=4, pad=0):
    """A CompressedTensor shaped like compress_params would emit."""
    from repro.sparse_infer.compress import CompressedTensor

    rows = dense_shape[-2] * n // m
    v_shape = dense_shape[:-2] + (rows, dense_shape[-1] + pad)
    return CompressedTensor(
        np.zeros(v_shape, np.float32), np.zeros(v_shape, np.uint8),
        n, m, len(dense_shape) - 2, dense_shape, pad,
    )


def test_compressed_pspec_tp_on_non_compressed_dim():
    """wq's dense rule puts TP on the output dim — the compressed leaf
    keeps it there (the values' reduction dim shrank, output didn't)."""
    from repro.distributed.compressed_pspecs import compressed_pspec

    v, i = compressed_pspec("head_0/attn/wq", _ct("wq", (64, 64)), MESH24)
    assert v == P(None, "model") and i == P(None, "model")


def test_compressed_pspec_compressed_dim_whole_groups():
    """wo's dense rule TP-shards the reduction (= compressed) dim: kept
    only when the *dense* dim divides by M x axis_size (whole N:M groups
    per shard), else TP falls back to the output dim."""
    from repro.distributed.compressed_pspecs import compressed_pspec

    # dense in = 64, m*size = 16: whole groups per shard -> stays
    v, _ = compressed_pspec("head_0/attn/wo", _ct("wo", (64, 64)), MESH24)
    assert v == P("model", None)
    # dense in = 24: 24 % 16 != 0 -> groups would straddle; moves to out
    v, _ = compressed_pspec("head_0/attn/wo", _ct("wo", (24, 64)), MESH24)
    assert v == P(None, "model")
    # ... unless the out dim doesn't divide either: fully replicated
    v, _ = compressed_pspec("head_0/attn/wo", _ct("wo", (24, 6)), MESH24)
    assert v == P(None, None)


def test_compressed_pspec_scan_stacked_body_leaves():
    """Stacked ``body/`` leaves keep the leading layer axis unsharded and
    apply the same group rule at the shifted reduction axis."""
    from repro.distributed.compressed_pspecs import compressed_pspec

    v, i = compressed_pspec(
        "body/sb_0/attn/wo", _ct("wo", (4, 64, 64)), MESH24
    )
    assert v == P(None, "model", None) and i == P(None, "model", None)
    v, _ = compressed_pspec(
        "body/sb_0/mlp/w_gate", _ct("w_gate", (4, 64, 128)), MESH24
    )
    assert v == P(None, None, "model")


def test_compressed_pspec_alignment_pad_participates():
    """MXU padding columns ride on the stored shape: an out dim of 60+4
    pad divides a 4-way axis even though the dense width wouldn't."""
    from repro.distributed.compressed_pspecs import compressed_pspec

    v, _ = compressed_pspec("head_0/attn/wq", _ct("wq", (64, 60), pad=4), MESH24)
    assert v == P(None, "model")


def test_serving_pspecs_head_gate_relocates_tp():
    """TP through a partially-sharded head dim (n_kv=2 on a 4-way axis)
    relocates to the reduction dim: whole heads per shard or psum."""
    from repro.distributed.compressed_pspecs import serving_param_pspecs

    cfg = dataclasses.replace(
        __import__("repro.configs", fromlist=["get_config"]).get_config(
            "gpt2-paper", smoke=True
        ),
        n_kv=2,
    )
    tree = {
        "head_0": {
            "attn": {
                "wk": np.zeros((64, 32), np.float32),
                "wq": np.zeros((64, 64), np.float32),
                "wk_c": _ct("wk", (64, 32)),
            }
        }
    }
    specs = serving_param_pspecs(tree, MESH24, cfg=cfg)
    # n_heads=4 divides the 4-way axis: q keeps output TP
    assert specs["head_0"]["attn"]["wq"] == P(None, "model")
    # n_kv=2 doesn't: k moves to the reduction dim (dense and compressed)
    assert specs["head_0"]["attn"]["wk"] == P("model", None)
    assert specs["head_0"]["attn"]["wk_c"].values == P("model", None)


def test_serving_pspecs_no_tp_orphan_weights():
    """Leaves whose dense rule is FSDP-only (MLA w_dkv) still serve
    sharded: reduction-dim TP instead of full replication."""
    from repro.configs import get_config
    from repro.distributed.compressed_pspecs import serving_param_pspecs

    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    tree = {"head_0": {"attn": {"w_dkv": np.zeros((64, 40), np.float32)}}}
    specs = serving_param_pspecs(tree, MESH24, cfg=cfg)
    assert specs["head_0"]["attn"]["w_dkv"] == P("model", None)


def test_compress_decompress_roundtrip():
    cfg = SparsityConfig(default=NMSparsity(2, 4))
    params = {"blk": {"w_gate": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}}
    # make it exactly 2:4 first (a trained-and-exported model)
    from repro.core.masking import nm_mask

    params = jax.tree_util.tree_map(lambda w: w * nm_mask(w, 2, 4, 0), params)
    comp = compress_params(params, cfg)
    rep = compression_report(params, comp)
    assert rep["ratio"] < 0.8  # values half + uint8 indices
    back = decompress_params(comp)
    np.testing.assert_allclose(
        np.asarray(back["blk"]["w_gate"]), np.asarray(params["blk"]["w_gate"])
    )


def test_hlo_cost_walker_scan_and_collective():
    from jax.sharding import Mesh, NamedSharding

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, x).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 2 * 64**3 * 7
    assert r["unknown_trip_count_whiles"] == 0
