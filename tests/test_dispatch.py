"""kernels.dispatch routing + the XLA nm_spmm production path + compress-
time padding (the no-interpret-in-the-hot-loop satellites of the paged-
attention PR)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.kernels import dispatch, ref
from repro.kernels.nm_spmm import (
    GATHER_ROWS,
    nm_spmm_pallas,
    nm_spmm_xla,
    pallas_shape_ok,
    pick_bk,
)
from repro.kernels.ops import nm_spmm
from repro.sparse_infer import compress_params
from repro.sparse_infer.compress import CompressedTensor
from repro.models.layers import matmul

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_registry_has_all_modes():
    reg = dispatch.registered()
    # the two hot-path kernels carry the per-shard shard_map route
    for kernel in ("nm_spmm", "paged_attn"):
        assert set(reg[kernel]) == {"pallas", "interpret", "xla", "shard_map"}
    # the stats-emitting inner kernel and the mask kernel stay 3-mode
    for kernel in ("paged_attn_stats", "nm_mask"):
        assert set(reg[kernel]) == {"pallas", "interpret", "xla"}


def test_default_off_tpu_is_xla_never_interpret():
    """The seed pathology: no production route may hit the interpreter."""
    assert jax.default_backend() != "tpu"
    mode, _ = dispatch.resolve("nm_spmm", b=4, k=64, o=64, n=2, m=4)
    assert mode == "xla"
    mode, _ = dispatch.resolve("paged_attn", b=2, n_slots=4, page_size=8)
    assert mode == "xla"
    assert not dispatch.uses_kernel("paged_attn", b=2, n_slots=4, page_size=8)


def test_force_mode_and_env_override(monkeypatch):
    with dispatch.force_mode("interpret"):
        assert dispatch.resolve("nm_spmm", b=1, k=64, o=64, n=2, m=4)[0] == "interpret"
        with dispatch.force_mode("xla"):  # innermost wins
            assert dispatch.resolve("nm_spmm", b=1, k=64, o=64, n=2, m=4)[0] == "xla"
    monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
    assert dispatch.resolve("paged_attn", b=1, n_slots=2, page_size=8)[0] == "interpret"
    monkeypatch.setenv(dispatch.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        dispatch.resolve("paged_attn", b=1, n_slots=2, page_size=8)


def test_explicit_mode_beats_force():
    with dispatch.force_mode("xla"):
        assert dispatch.resolve("nm_spmm", mode="interpret")[0] == "interpret"


def test_ops_wrapper_modes():
    """The legacy prefer_pallas/interpret knobs are retired: every route is
    a dispatch mode, and all modes agree with the oracle."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    v, i = ref.nm_compress(w, 2, 4, 0)
    yr = ref.nm_spmm_ref(x, v, i, 2, 4)
    for kw in (dict(mode="xla"), dict(mode="interpret"), dict()):
        y = nm_spmm(x, v, i, 2, 4, **kw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    import inspect

    from repro.kernels import ops

    for fn in (ops.nm_spmm, ops.nm_mask_apply):
        params = inspect.signature(fn).parameters
        assert "prefer_pallas" not in params and "interpret" not in params
        assert "mode" in params


def test_nm_mask_dispatch_unsupported_shape_falls_to_xla():
    """3-D / non-group-aligned weights take the reference on every mode —
    a forced interpret sweep must not trip the kernel's 2-D assert."""
    w3 = jax.random.normal(jax.random.PRNGKey(2), (16, 8, 4))
    with dispatch.force_mode("interpret"):
        mask, masked = dispatch.nm_mask(w3, 2, 4)
    np.testing.assert_array_equal(
        np.asarray(mask), np.asarray(ref.nm_mask(w3, 2, 4, 0))
    )
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(mask * w3))


# ---------------------------------------------------------------------------
# gcd block pick + shape guard (no decrement scans, no degenerate grids)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,n,m,expect", [
    (512, 2, 4, 512), (384, 1, 4, 128), (768, 4, 8, 256),
    (96, 2, 8, 32), (4096, 8, 32, 512),
])
def test_pick_bk_valid_and_large(k, n, m, expect):
    bk = pick_bk(k, n, m)
    assert k % bk == 0 and (bk * n) % m == 0
    assert bk == expect


def test_unaligned_o_uses_runtime_pad_fallback():
    """An unpadded (CPU-exported) artifact with a non-gcd-friendly output
    width still runs on the Pallas route via the runtime pad."""
    assert pallas_shape_ok(4, 64, 300, 2, 4)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 300))
    v, i = ref.nm_compress(w, 2, 4, 0)
    y = nm_spmm_pallas(x, v, i, 2, 4, interpret=True)
    assert y.shape == (4, 300)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.nm_spmm_ref(x, v, i, 2, 4)), atol=1e-4
    )


def test_degenerate_k_routes_to_xla():
    # 514 = 2·257: the only valid blocks are 2 and 514 — old code scanned
    # down to bk=2; the guard refuses the Pallas route instead
    assert pick_bk(514, 2, 4) == 2
    assert not pallas_shape_ok(1, 514, 256, 2, 4)
    assert pallas_shape_ok(1, 512, 256, 2, 4)


# ---------------------------------------------------------------------------
# XLA production path: both regimes vs the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b", [1, GATHER_ROWS, GATHER_ROWS + 1, 64])
@pytest.mark.parametrize("n,m", [(2, 4), (1, 4), (4, 8)])
def test_nm_spmm_xla_matches_ref(b, n, m):
    k, o = 128, 96
    x = jax.random.normal(jax.random.PRNGKey(b), (b, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, o), jnp.float32)
    v, i = ref.nm_compress(w, n, m, 0)
    np.testing.assert_allclose(
        np.asarray(nm_spmm_xla(x, v, i, n, m)),
        np.asarray(ref.nm_spmm_ref(x, v, i, n, m)),
        atol=1e-4, rtol=1e-4,
    )


def test_nm_spmm_xla_bf16():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.bfloat16)
    v, i = ref.nm_compress(w, 2, 4, 0)
    y = nm_spmm_xla(x, v, i, 2, 4)
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y, np.float32),
        np.asarray(ref.nm_spmm_ref(x, v, i, 2, 4), np.float32),
        atol=0.3, rtol=1e-2,
    )


# ---------------------------------------------------------------------------
# compress-time MXU alignment (padding hoisted out of the kernel call)
# ---------------------------------------------------------------------------


def _tree(seed=0, d=64, o=48):
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, o), jnp.float32)
    return {"blk": {"w_fc": w}}


def test_compress_align_pads_and_slices():
    params = _tree()
    cfg = core.SparsityConfig(default=core.NMSparsity(2, 4))
    comp = compress_params(params, cfg, align=128)
    ct = comp["blk"]["w_fc"]
    assert isinstance(ct, CompressedTensor)
    assert ct.values.shape[-1] == 128 and ct.pad == 80
    assert ct.out_features == 48 and ct.shape == (64, 48)
    # padding never leaks: dense() and both kernel routes slice it off
    np.testing.assert_allclose(
        np.asarray(ct.dense()),
        np.asarray(compress_params(params, cfg, align=1)["blk"]["w_fc"].dense()),
    )
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    y_ref = ref.nm_spmm_ref(
        x, *ref.nm_compress(params["blk"]["w_fc"], 2, 4, 0), 2, 4
    )
    y_x = matmul(x, ct)
    assert y_x.shape == (4, 48)
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_ref), atol=1e-4)
    y_p = nm_spmm_pallas(
        x, ct.values, ct.indices, 2, 4, o_true=ct.out_features, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref), atol=1e-4)


def test_compress_default_off_tpu_unpadded():
    comp = compress_params(
        _tree(), core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    ct = comp["blk"]["w_fc"]
    assert ct.pad == 0 and ct.values.shape[-1] == 48


def test_aligned_artifact_skips_runtime_pad(monkeypatch):
    """With an MXU-aligned artifact the Pallas wrapper must not re-pad the
    compressed operands per call (the hoist satellite)."""
    import repro.kernels.nm_spmm as mod
    params = _tree(o=128)
    cfg = core.SparsityConfig(default=core.NMSparsity(2, 4))
    ct = compress_params(params, cfg, align=128)["blk"]["w_fc"]
    assert ct.pad == 0
    called = []
    orig = jnp.pad
    monkeypatch.setattr(mod.jnp, "pad", lambda *a, **k: called.append(a) or orig(*a, **k))
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64))
    nm_spmm_pallas(x, ct.values, ct.indices, 2, 4, interpret=True)
    padded = [a for a in called if getattr(a[0], "shape", None) == ct.values.shape]
    assert not padded
