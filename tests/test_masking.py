"""Unit + property tests for the N:M masking math (paper Eq. 8/9 substrate).

hypothesis is an optional dependency: without it the fixed-case tests still
run and the property sweeps are skipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or a skip shim

from repro.core import masking as mk

jax.config.update("jax_platform_name", "cpu")


NM_CASES = [(1, 4), (2, 4), (3, 4), (1, 8), (2, 8), (4, 8), (1, 16), (4, 16), (8, 32)]


@pytest.mark.parametrize("n,m", NM_CASES)
@pytest.mark.parametrize("axis", [0, 1])
def test_mask_exact_n_per_group(n, m, axis):
    w = jax.random.normal(jax.random.PRNGKey(0), (m * 3, m * 2))
    mask = mk.nm_mask(w, n, m, axis)
    wt = jnp.moveaxis(mask, axis, -1)
    groups = wt.reshape(wt.shape[0], -1, m)
    counts = groups.sum(-1)
    assert (counts == n).all(), counts


@pytest.mark.parametrize("n,m", NM_CASES)
def test_mask_keeps_largest(n, m):
    w = jax.random.normal(jax.random.PRNGKey(1), (m * 4, 8))
    mask = mk.nm_mask(w, n, m, 0)
    aw = jnp.abs(w)
    groups = jnp.moveaxis(aw, 0, -1).reshape(8, -1, m)
    gm = jnp.moveaxis(mask, 0, -1).reshape(8, -1, m)
    kept_min = jnp.where(gm > 0, groups, jnp.inf).min(-1)
    dropped_max = jnp.where(gm == 0, groups, -jnp.inf).max(-1)
    assert (kept_min >= dropped_max).all()


def test_mask_n_equals_m_is_dense():
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    assert (mk.nm_mask(w, 4, 4, 0) == 1).all()


def test_mask_indivisible_raises():
    w = jnp.zeros((10, 8))
    with pytest.raises(ValueError):
        mk.nm_mask(w, 2, 4, 0)


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from(NM_CASES),
    st.integers(1, 4),
    st.integers(1, 3),
    st.integers(0, 2**31 - 1),
)
def test_compress_roundtrip_property(nm, g, cols, seed):
    n, m = nm
    w = jax.random.normal(jax.random.PRNGKey(seed), (g * m, cols * 4))
    mask = mk.nm_mask(w, n, m, 0)
    v, i = mk.nm_compress(w, n, m, 0)
    assert v.shape == (g * n, cols * 4)
    assert i.dtype == jnp.uint8
    dense = mk.nm_decompress(v, i, n, m, 0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(mask * w), rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([(1, 4), (2, 4), (2, 8)]), st.integers(0, 2**31 - 1))
def test_dynamic_matches_static(nm, seed):
    n, m = nm
    w = jax.random.normal(jax.random.PRNGKey(seed), (m * 5, 12))
    static = mk.nm_mask(w, n, m, 0)
    dynamic = mk.nm_mask_dynamic(w, jnp.asarray(n), m, 0)
    np.testing.assert_array_equal(np.asarray(static), np.asarray(dynamic))


def test_straight_through_gradient_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 4))
    mask = mk.nm_mask(w, 2, 4, 0)
    # f = sum(sin(masked_w)); STE grad must be cos evaluated at masked point,
    # WITHOUT the mask factor (pruned coords still receive gradient)
    g = jax.grad(lambda w: jnp.sum(jnp.sin(mk.straight_through_mask(w, mask))))(w)
    expected = jnp.cos(w * mask)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), rtol=1e-6)


def test_masked_no_ste_kills_pruned_grads():
    w = jax.random.normal(jax.random.PRNGKey(4), (8, 4))
    mask = mk.nm_mask(w, 2, 4, 0)
    g = jax.grad(lambda w: jnp.sum(jnp.sin(mk.masked_no_ste(w, mask))))(w)
    assert (np.asarray(g)[np.asarray(mask) == 0] == 0).all()


def test_sr_ste_term_only_on_pruned():
    w = jax.random.normal(jax.random.PRNGKey(5), (8, 4))
    mask = mk.nm_mask(w, 2, 4, 0)
    term = mk.sr_ste_grad_term(w, mask, 0.5)
    np.testing.assert_allclose(np.asarray(term), np.asarray(0.5 * (1 - mask) * w))


def test_3d_weights_supported():
    # MoE expert stacks (E, d, f) with groups along d (axis 1)
    w = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 8))
    mask = mk.nm_mask(w, 2, 4, 1)
    groups = jnp.moveaxis(mask, 1, -1).reshape(4, 8, 4, 4).sum(-1)
    assert (groups == 2).all()
