"""Device-resident scheduler: run-until-stop decode, on-device lane
refill, async double-buffered token streams.

Load-bearing guarantees:

1. **Scheduler equivalence** — greedy *and* sampled token streams are
   bit-identical between the fixed-K sync engine and every device-
   scheduler variant ({run-until-stop} × {staged refill} × {async
   double-buffer}) over {slab, paged} × {compressed, dense}, on a single
   device and on an emulated (2,4) mesh.  Sampling keys derive from
   (request uid, token index) — ``sampling.request_keys`` — so the
   stream cannot depend on lanes, batch-mates, or dispatch cuts.
2. **Mid-loop freezes** — EOS, token-budget, and logical-capacity stops
   detected inside the while-loop freeze lanes exactly where the host
   replay finishes them (same rules, same tokens, same finish reasons).
3. **On-device refill** — with more requests than lanes, frozen lanes
   are swapped for staged prompts inside the dispatch (``refills > 0``)
   and the refilled requests' streams match their sync-scheduler runs,
   including the interaction with prefix-cached shared pages.
4. **Async ordering** — with a forced-slow host block fetch the
   double-buffered engine still replays blocks in dispatch order and
   produces identical streams.
5. **Windowed chunked prefill** — a sliding-window arch on the paged
   layout absorbs long prompts chunk-by-chunk (windowed ring views)
   bit-identically to monolithic prefill; the slab stays gated off.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams

from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _trees(arch: str, **overrides):
    cfg = get_config(arch, smoke=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    sparse = recipe.export_sparse(params)
    return cfg, model, sparse, compress_params(sparse, recipe.sparsity)


CFG, MODEL, SPARSE, COMP = _trees("gpt2-paper")


def _rand_prompt(seed, n, vocab=None):
    vocab = vocab or CFG.vocab
    return [
        int(t)
        for t in jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab)
    ]


def _mixed_load(n=6, gen=8, eos_id=-1):
    """More requests than lanes, mixed greedy/sampled, staggered budgets."""
    prompts = [_rand_prompt(50 + r, 2 + (r % 4)) for r in range(n)]
    sps = []
    for r in range(n):
        if r % 3 == 1:
            sps.append(SamplingParams(
                temperature=0.8, top_k=7, max_new_tokens=gen - r % 2,
                eos_id=eos_id,
            ))
        else:
            sps.append(SamplingParams(
                max_new_tokens=gen + (r % 3), eos_id=eos_id,
            ))
    return prompts, sps


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return (
        [res[u].tokens for u in uids],
        [res[u].finish_reason for u in uids],
    )


def _run(tree, prompts, sps, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    eng = DecodeEngine(MODEL, tree, seed=11, **kw)
    return _stream(eng, prompts, sps), eng


DEVICE_VARIANTS = [
    dict(max_steps_per_dispatch=5),
    dict(max_steps_per_dispatch=5, staged_lanes=2),
    dict(max_steps_per_dispatch=5, staged_lanes=2, async_stream=True),
]


# ---------------------------------------------------------------------------
# scheduler equivalence: sync fixed-K vs device variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
def test_scheduler_equivalence_compressed(paged):
    prompts, sps = _mixed_load()
    pkw = dict(num_pages=64, page_size=4) if paged else {}
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=4, **pkw)
    for variant in DEVICE_VARIANTS:
        got, eng = _run(COMP, prompts, sps, **variant, **pkw)
        assert got == base, variant
        if variant.get("staged_lanes"):
            assert eng.refills > 0  # swaps actually happened on device
        if variant.get("async_stream"):
            assert eng.dispatches == 2 * eng.cycles  # double-buffered


def test_scheduler_equivalence_dense_tree():
    prompts, sps = _mixed_load(n=4)
    base, _ = _run(SPARSE, prompts, sps, steps_per_dispatch=2)
    got, _ = _run(
        SPARSE, prompts, sps,
        max_steps_per_dispatch=6, staged_lanes=2, async_stream=True,
    )
    assert got == base


def test_run_until_stop_amortizes_host_syncs():
    """Uniform long generations: the while-loop runs to its bound, so the
    device scheduler syncs the host strictly fewer times than the
    equal-K sync engine dispatches."""
    prompts = [_rand_prompt(70 + r, 3) for r in range(2)]
    sps = [SamplingParams(max_new_tokens=12) for _ in prompts]
    base, sync_eng = _run(COMP, prompts, sps, steps_per_dispatch=4)
    got, dev_eng = _run(COMP, prompts, sps, max_steps_per_dispatch=12)
    assert got == base
    assert dev_eng.stats()["host_syncs"] < sync_eng.stats()["host_syncs"]
    assert dev_eng.stats()["scheduler"] == "device"


# ---------------------------------------------------------------------------
# mid-loop freezes: EOS / budget / capacity
# ---------------------------------------------------------------------------


def test_midloop_eos_freeze_matches_sync():
    """Pick an EOS id off a baseline greedy stream so it actually fires
    mid-loop; all variants must finish that lane identically."""
    prompts = [_rand_prompt(90 + r, 3) for r in range(3)]
    sps = [SamplingParams(max_new_tokens=10) for _ in prompts]
    (toks, _), _ = _run(COMP, prompts, sps, steps_per_dispatch=1)
    eos = toks[0][4]  # fires mid-while-loop for K=5
    sps = [SamplingParams(max_new_tokens=10, eos_id=eos) for _ in prompts]
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=1)
    assert "eos" in base[1]
    for variant in DEVICE_VARIANTS:
        got, _ = _run(COMP, prompts, sps, **variant)
        assert got == base, variant


@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
def test_midloop_capacity_and_budget_freezes(paged):
    """Tight max_len: some lanes hit logical capacity mid-loop (including
    refilled lanes whose prompt+budget overruns it), others exhaust
    budgets of different parities."""
    prompts = [_rand_prompt(120 + r, 4 + r) for r in range(5)]
    sps = [
        SamplingParams(max_new_tokens=3 + 4 * r) for r in range(5)
    ]
    pkw = dict(num_pages=64, page_size=2) if paged else {}
    base, _ = _run(COMP, prompts, sps, max_len=14, steps_per_dispatch=3, **pkw)
    assert "cache_full" in base[1] and "length" in base[1]
    for variant in DEVICE_VARIANTS:
        got, _ = _run(COMP, prompts, sps, max_len=14, **variant, **pkw)
        assert got == base, variant


# ---------------------------------------------------------------------------
# refill × prefix cache, and refill into preempt-resumed requests
# ---------------------------------------------------------------------------


def test_refill_with_prefix_cache_shared_pages():
    """Staged refills write fresh pages while earlier admissions share
    prefix-cached (refcounted, COW) pages: streams must still match the
    prefix-less sync engine."""
    head = _rand_prompt(7, 6)
    prompts = [head + _rand_prompt(200 + r, 2 + r % 3) for r in range(6)]
    sps = [SamplingParams(max_new_tokens=6 + r % 4) for r in range(6)]
    pkw = dict(num_pages=96, page_size=2)
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=2, **pkw)
    # staged_lanes=1 so the overflow splits between device refills and
    # later host admissions — the latter hit the prefix index (refills
    # deliberately bypass it; see engine docstring).
    got, eng = _run(
        COMP, prompts, sps, prefix_cache=True,
        max_steps_per_dispatch=5, staged_lanes=1, async_stream=True, **pkw,
    )
    assert got == base
    assert eng.refills > 0
    assert eng.prefix_hits > 0  # queue admissions still hit the index


def test_refill_under_pool_pressure_preempts_and_resumes():
    """An undersized pool: staging backs off (stage_alloc refuses), lanes
    preempt and resume, and the device scheduler still reproduces the
    sync streams token for token."""
    prompts = [_rand_prompt(300 + r, 3) for r in range(4)]
    sps = [SamplingParams(max_new_tokens=10) for _ in prompts]
    pkw = dict(num_pages=26, page_size=2, max_len=16)
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=2, **pkw)
    got, eng = _run(
        COMP, prompts, sps,
        max_steps_per_dispatch=4, staged_lanes=2, **pkw,
    )
    assert got == base


# ---------------------------------------------------------------------------
# async double-buffering under forced-slow host reads
# ---------------------------------------------------------------------------


def test_async_stream_forced_slow_fetch_keeps_order():
    prompts, sps = _mixed_load(n=5)
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=4)
    eng = DecodeEngine(
        MODEL, COMP, max_batch=2, max_len=32, seed=11,
        max_steps_per_dispatch=5, staged_lanes=2, async_stream=True,
    )
    fetched = []

    def slow_fetch(block):
        time.sleep(0.02)  # dispatch N+1 finishes well before this returns
        hb = np.asarray(block)
        fetched.append(hb.shape)
        return hb

    eng._fetch_block = slow_fetch
    got = _stream(eng, prompts, sps)
    assert got == base
    assert len(fetched) == eng.dispatches == 2 * eng.cycles
    assert eng.stats()["block_fetches"] == eng.dispatches
    assert eng.stats()["itl_ms_p99"] >= eng.stats()["itl_ms_p50"] > 0


# ---------------------------------------------------------------------------
# windowed chunked prefill (paged ring views); slab stays gated
# ---------------------------------------------------------------------------


def test_windowed_chunked_prefill_parity_paged():
    cfg, model, _, comp = _trees("gpt2-paper", local_window=8)
    prompts = [_rand_prompt(400 + r, 11 + r, cfg.vocab) for r in range(3)]
    sps = [SamplingParams(max_new_tokens=5) for _ in prompts]

    def run(**kw):
        eng = DecodeEngine(
            model, comp, max_batch=2, max_len=32, seed=3,
            num_pages=64, page_size=4, **kw,
        )
        return _stream(eng, prompts, sps), eng

    base, _ = run()
    got, eng = run(prefill_chunk=4)
    assert eng.prefill_chunk == 4  # the windowed gate is lifted on paged
    assert got == base
    assert eng.prefill_chunks > 0


def test_windowed_chunked_prefill_device_scheduler():
    """Chunked windowed prompts drain fully at the cycle boundary, then
    the lanes join the run-until-stop loop; streams match monolithic."""
    cfg, model, _, comp = _trees("gpt2-paper", local_window=8)
    prompts = [_rand_prompt(500 + r, 10 + 2 * r, cfg.vocab) for r in range(4)]
    sps = [SamplingParams(max_new_tokens=6) for _ in prompts]

    def run(**kw):
        eng = DecodeEngine(
            model, comp, max_batch=2, max_len=32, seed=3,
            num_pages=96, page_size=4, **kw,
        )
        return _stream(eng, prompts, sps), eng

    base, _ = run()
    got, eng = run(
        prefill_chunk=4, max_steps_per_dispatch=5, staged_lanes=2,
        async_stream=True,
    )
    assert got == base
    assert eng.prefill_chunks > 0


def test_windowed_chunked_prefill_stays_gated_on_slab():
    cfg, model, _, comp = _trees("gpt2-paper", local_window=8)
    eng = DecodeEngine(model, comp, max_batch=1, max_len=32, prefill_chunk=4)
    assert eng.prefill_chunk is None  # slab has no window ring to view
    prompts = [_rand_prompt(600, 12, cfg.vocab)]
    sps = [SamplingParams(max_new_tokens=4)]
    base = _stream(
        DecodeEngine(model, comp, max_batch=1, max_len=32, donate=False),
        prompts, sps,
    )
    assert _stream(eng, prompts, sps) == base
    assert eng.prefill_chunks == 0


# ---------------------------------------------------------------------------
# emulated (2,4) mesh parity
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("paged", [False, True], ids=["slab", "paged"])
def test_device_scheduler_mesh_parity(paged):
    from repro.launch.mesh import make_local_mesh

    prompts, sps = _mixed_load(n=5)
    pkw = dict(num_pages=64, page_size=4) if paged else {}
    base, _ = _run(COMP, prompts, sps, steps_per_dispatch=4, **pkw)
    mesh = make_local_mesh(4, data=2)
    got, eng = _run(
        COMP, prompts, sps, mesh=mesh,
        max_steps_per_dispatch=5, staged_lanes=2, async_stream=True, **pkw,
    )
    assert got == base
    assert eng.refills > 0
