"""AutoSwitch (Algorithm 2) and the baseline switching criteria."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autoswitch import (
    AutoSwitchConfig,
    autoswitch_step,
    criterion_autoswitch_offline,
    criterion_relative_norm,
    criterion_staleness,
    init_autoswitch,
    variance_change_sample,
)

jax.config.update("jax_platform_name", "cpu")


def test_window_size_matches_paper():
    # T_w = floor(1/(1-beta2))
    assert AutoSwitchConfig(beta2=0.999).t_w == 1000
    assert AutoSwitchConfig(beta2=0.99).t_w == 100
    assert AutoSwitchConfig(beta2=0.9).t_w == 10
    assert AutoSwitchConfig(beta2=0.999, window=17).t_w == 17


def test_incremental_identity_matches_direct_diff():
    """Z_t from (g, v_t) must equal d^{-1}||v_{t+1} - v_t||_1 exactly."""
    cfg = AutoSwitchConfig(beta2=0.9)
    g = {"a": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5, 3.0]])}
    v = {"a": jnp.array([0.4, 0.1]), "b": jnp.array([[1.0, 2.0]])}
    v_next = jax.tree_util.tree_map(
        lambda vv, gg: cfg.beta2 * vv + (1 - cfg.beta2) * gg**2, v, g
    )
    direct = (
        sum(
            jnp.sum(jnp.abs(a - b))
            for a, b in zip(jax.tree_util.tree_leaves(v_next), jax.tree_util.tree_leaves(v))
        )
        / 4.0
    )
    z = variance_change_sample(g, v, cfg)
    np.testing.assert_allclose(float(z), float(direct), rtol=1e-6)


def test_option_ii_geometric():
    cfg = AutoSwitchConfig(beta2=0.9, option="II")
    g = {"a": jnp.array([1.0, 2.0])}
    v = {"a": jnp.array([0.0, 0.0])}
    z = variance_change_sample(g, v, cfg)
    # geometric mean of (0.1*[1,4]) = sqrt(0.1*0.4)
    np.testing.assert_allclose(float(z), float(jnp.sqrt(0.1 * 0.4)), rtol=1e-4)


def test_switch_fires_only_after_full_window_below_eps():
    cfg = AutoSwitchConfig(beta2=0.9, eps=1e-3, window=5)
    state = init_autoswitch(cfg)
    fired_at = None
    for t in range(1, 20):
        z = jnp.asarray(1e-4)  # always below eps
        state, zbar, crit = autoswitch_step(state, z, jnp.asarray(t), cfg)
        if bool(crit) and fired_at is None:
            fired_at = t
    assert fired_at == 5  # needs a full window first


def test_clipping_bounds():
    cfg = AutoSwitchConfig(beta2=0.9, eps=1e-9, window=3, t_min=5, t_max=10)
    state = init_autoswitch(cfg)
    fired = []
    for t in range(1, 15):
        z = jnp.asarray(1.0)  # never below eps
        state, _, crit = autoswitch_step(state, z, jnp.asarray(t), cfg)
        if bool(crit):
            fired.append(t)
    assert fired and fired[0] == 11  # forced by t_max

    cfg2 = AutoSwitchConfig(beta2=0.9, eps=1e9, window=3, t_min=6)
    state = init_autoswitch(cfg2)
    fired = []
    for t in range(1, 12):
        state, _, crit = autoswitch_step(state, jnp.asarray(0.0), jnp.asarray(t), cfg2)
        if bool(crit):
            fired.append(t)
    assert fired[0] == 7  # eps satisfied immediately but t_min delays


def test_offline_matches_online():
    cfg = AutoSwitchConfig(beta2=0.9, eps=0.5, window=4)
    z_trace = np.array([2.0, 1.5, 1.0, 0.9, 0.4, 0.3, 0.2, 0.2, 0.1, 0.1])
    state = init_autoswitch(cfg)
    online = None
    for t, z in enumerate(z_trace, start=1):
        state, _, crit = autoswitch_step(state, jnp.asarray(z), jnp.asarray(t), cfg)
        if bool(crit) and online is None:
            online = t - 1  # offline uses 0-based indices
    offline = criterion_autoswitch_offline(z_trace, cfg)
    assert online == offline


def test_baseline_criteria_shapes():
    # Eq. 10: relative norm change < 0.5
    v_norms = np.array([1.0, 10.0, 12.0, 12.5, 12.6])
    t = criterion_relative_norm(v_norms)
    assert t == 2  # 12 vs 10 -> 0.2 < 0.5 at step 2
    # Eq. 11: staleness ratio > 0.96 with k = 10 (beta2=0.9)
    v_l1 = np.concatenate([np.linspace(1, 20, 15), np.full(10, 20.0)])
    t2 = criterion_staleness(v_l1, beta2=0.9)
    assert t2 >= 10
