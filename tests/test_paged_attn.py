"""Paged decode-attention kernel parity (interpret mode on CPU).

Locks the tentpole guarantees of ``kernels/paged_attn.py``:

1. kernel (Pallas interpret) ≡ XLA oracle ≡ ``layers.decode_attention`` on
   the gathered contiguous view, over GQA, MLA-latent, sliding-window and
   ragged heterogeneous lane lengths — including lanes whose tables hold
   sentinel (unmapped) slots and fully idle lanes (all-sentinel → exact
   zeros, never NaN).
2. model-level: ``decode_step`` through the paged fast path matches the
   gathered reference path — bit-comparable for GQA/windowed (same op
   order per page), documented fp-tolerance for MLA (absorbed-latent
   reorders the projections).
3. engine-level: a forced-kernel engine reproduces the reference engine's
   greedy stream on attention and windowed archs.

Accumulation order differs between flash-over-pages and one-shot softmax,
so kernel-vs-oracle assertions use fp tolerances (f32: 1e-5, documented in
the module docstring) rather than bit equality.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.kernels import dispatch
from repro.kernels.paged_attn import paged_attn_pallas, paged_attn_xla
from repro.models.layers import decode_attention
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, PagedKVPool, SamplingParams
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

F32_TOL = dict(atol=1e-5, rtol=1e-5)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def _full_tables(lengths, ps, n_slots, num_pages):
    """Append-only tables: distinct pages for every lane's live prefix."""
    b = len(lengths)
    t = np.full((b, n_slots), num_pages, np.int32)
    nxt = 0
    for i, ln in enumerate(lengths):
        for pg in range(-(-ln // ps)):
            t[i, pg] = nxt % num_pages
            nxt += 1
    return jnp.asarray(t)


def _win_tables(lengths, ps, win, win_slots, num_pages):
    """Modular windowed tables mapping each lane's live pages."""
    b = len(lengths)
    t = np.full((b, win_slots), num_pages, np.int32)
    nxt = 0
    for i, ln in enumerate(lengths):
        if ln == 0:
            continue
        start = max(0, ln - win)
        for pg in range(start // ps, (ln - 1) // ps + 1):
            t[i, pg % win_slots] = nxt % num_pages
            nxt += 1
    return jnp.asarray(t)


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, F32_TOL),
    (jnp.bfloat16, dict(atol=2e-2, rtol=2e-2)),
])
def test_gqa_ragged_with_sentinels(dtype, tol):
    """Heterogeneous lane lengths; trailing slots are sentinel; one lane
    fully idle (all-sentinel table)."""
    b, hkv, g, d, ps, num_pages, n_slots = 4, 2, 3, 16, 4, 12, 6
    lengths = [1, 7, 21, 0]  # partial page / multi-page / near-cap / idle
    q = _rand(0, (b, hkv, g, d), dtype)
    k_pages = _rand(1, (num_pages, ps, hkv, d), dtype)
    v_pages = _rand(2, (num_pages, ps, hkv, d), dtype)
    tables = _full_tables(lengths, ps, n_slots, num_pages)
    lens = jnp.asarray(lengths, jnp.int32)
    scale = d ** -0.5

    y_k = paged_attn_pallas(
        q, k_pages, v_pages, tables, lens, scale=scale, interpret=True
    )
    y_x = paged_attn_xla(q, k_pages, v_pages, tables, lens, scale=scale)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_x, np.float32), **tol
    )
    # idle lane: exact zeros from both
    assert float(jnp.max(jnp.abs(y_k[3]))) == 0.0
    assert float(jnp.max(jnp.abs(y_x[3]))) == 0.0

    # against decode_attention on the gathered contiguous view, per lane
    for i, ln in enumerate(lengths):
        if ln == 0:
            continue
        pages = np.asarray(tables)[i, : -(-ln // ps)]
        kv = k_pages[pages].reshape(1, -1, hkv, d)
        vv = v_pages[pages].reshape(1, -1, hkv, d)
        ref = decode_attention(
            q[i].reshape(1, 1, hkv * g, d), kv, vv, jnp.asarray([ln])
        )
        np.testing.assert_allclose(
            np.asarray(y_k[i], np.float32).reshape(1, 1, hkv * g, d),
            np.asarray(ref, np.float32),
            **tol,
        )


def test_sliding_window_modular_table():
    """Windowed lanes visit only live pages; expired/unmapped slots skip."""
    b, hkv, g, d, ps, num_pages = 3, 1, 4, 8, 4, 10
    win, win_slots = 6, 3  # ceil(6/4)+1
    lengths = [3, 9, 0]  # pre-boundary / slid-past-a-page / idle
    q = _rand(3, (b, hkv, g, d))
    k_pages = _rand(4, (num_pages, ps, hkv, d))
    v_pages = _rand(5, (num_pages, ps, hkv, d))
    tables = _win_tables(lengths, ps, win, win_slots, num_pages)
    lens = jnp.asarray(lengths, jnp.int32)
    scale = d ** -0.5

    y_k = paged_attn_pallas(
        q, k_pages, v_pages, tables, lens, scale=scale,
        window=win, win_slots=win_slots, interpret=True,
    )
    y_x = paged_attn_xla(
        q, k_pages, v_pages, tables, lens, scale=scale,
        window=win, win_slots=win_slots,
    )
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_x, np.float32), **F32_TOL
    )
    assert float(jnp.max(jnp.abs(y_k[2]))) == 0.0

    # reference: gather the live logical window per lane
    for i, ln in enumerate(lengths):
        if ln == 0:
            continue
        pos = np.arange(max(0, ln - win), ln)
        tb = np.asarray(tables)[i]
        kv = k_pages[tb[(pos // ps) % win_slots], pos % ps][None]
        vv = v_pages[tb[(pos // ps) % win_slots], pos % ps][None]
        ref = decode_attention(
            q[i].reshape(1, 1, hkv * g, d), kv, vv, jnp.asarray([len(pos)])
        )
        np.testing.assert_allclose(
            np.asarray(y_k[i], np.float32).reshape(1, 1, hkv * g, d),
            np.asarray(ref, np.float32),
            **F32_TOL,
        )


def test_mla_latent_v_is_k_and_second_stream():
    """MLA-latent layout: Hkv=1, V == K (latent pool read once), RoPE key
    as the second score stream."""
    b, h, latent, rd, ps, num_pages, n_slots = 3, 4, 16, 8, 4, 8, 4
    lengths = [5, 12, 2]
    ql = _rand(6, (b, 1, h, latent))
    q2 = _rand(7, (b, 1, h, rd))
    c_pages = _rand(8, (num_pages, ps, 1, latent))
    r_pages = _rand(9, (num_pages, ps, 1, rd))
    tables = _full_tables(lengths, ps, n_slots, num_pages)
    lens = jnp.asarray(lengths, jnp.int32)
    scale = 0.17

    y_k = paged_attn_pallas(
        ql, c_pages, None, tables, lens, scale=scale,
        q2=q2, k2_pages=r_pages, v_is_k=True, interpret=True,
    )
    y_x = paged_attn_xla(
        ql, c_pages, None, tables, lens, scale=scale,
        q2=q2, k2_pages=r_pages, v_is_k=True,
    )
    assert y_k.shape == (b, 1, h, latent)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_x, np.float32), **F32_TOL
    )

    # oracle: concatenated-score attention over the gathered latent view
    for i, ln in enumerate(lengths):
        pages = np.asarray(tables)[i, : -(-ln // ps)]
        cv = jnp.concatenate(
            [c_pages[pages].reshape(-1, latent), r_pages[pages].reshape(-1, rd)],
            axis=-1,
        )[: ps * len(pages)]
        qcat = jnp.concatenate([ql[i, 0], q2[i, 0]], axis=-1)  # (H, L+rd)
        s = (qcat.astype(jnp.float32) @ cv.T.astype(jnp.float32)) * scale
        mask = jnp.arange(cv.shape[0]) < ln
        s = jnp.where(mask[None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1) * mask[None]
        ref = p @ cv[:, :latent].astype(jnp.float32)
        np.testing.assert_allclose(
            np.asarray(y_k[i, 0], np.float32), np.asarray(ref), atol=1e-5,
            rtol=1e-4,
        )


# ---------------------------------------------------------------------------
# model / engine level
# ---------------------------------------------------------------------------


def _compressed(arch: str, seed=0):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    return cfg, model, compress_params(recipe.export_sparse(params), recipe.sparsity)


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return [res[u].tokens for u in uids], [res[u].finish_reason for u in uids]


def _prompts(cfg, lens, seed=40):
    return [
        [int(t) for t in jax.random.randint(
            jax.random.PRNGKey(seed + i), (ln,), 0, cfg.vocab
        )]
        for i, ln in enumerate(lens)
    ]


@pytest.mark.parametrize("arch,pages", [
    ("gpt2-paper", dict(num_pages=16, page_size=8)),
    ("recurrentgemma-9b", dict(num_pages=32, page_size=4)),
])
def test_engine_kernel_stream_matches_reference(arch, pages):
    """Forced-kernel paged engine ≡ reference paged engine, greedy tokens
    and finish reasons, over heterogeneous lanes (incl. slot reuse)."""
    cfg, model, comp = _compressed(arch)
    prompts = _prompts(cfg, [3, 6, 9, 12])
    sps = [SamplingParams(max_new_tokens=6 + r) for r in range(4)]
    kw = dict(max_batch=2, max_len=40, seed=3, **pages)
    t_ref, r_ref = _stream(DecodeEngine(model, comp, **kw), prompts, sps)
    with dispatch.force_mode("interpret"):
        t_fast, r_fast = _stream(DecodeEngine(model, comp, **kw), prompts, sps)
    assert t_fast == t_ref
    assert r_fast == r_ref


def test_mla_decode_step_logits_parity():
    """MLA absorbed-latent fast path: same cache writes, logits within the
    documented fp tolerance of the gathered+expanded reference (the
    absorption reorders the W_ukv projections, so parity is tolerance-
    level, not bitwise)."""
    cfg, model, comp = _compressed("deepseek-v2-lite-16b")
    pool = PagedKVPool(model, max_batch=2, max_len=24, num_pages=24, page_size=4)
    lens = [5, 9]
    toks = np.zeros((2, max(lens)), np.int32)
    for i, ln in enumerate(lens):
        toks[i, :ln] = np.asarray(_prompts(cfg, [ln], seed=60 + i)[0])
    for lane, ln in enumerate(lens):
        assert pool.alloc_prefill(lane, ln)
    cache = dict(pool.cache)
    cache["tables"] = pool.device_tables()
    _, _, produced = model.forward(
        comp, {"tokens": jnp.asarray(toks)}, remat=False, want_cache=True
    )
    cache = model.write_prefill(
        cache, produced, jnp.asarray([0, 1]), jnp.asarray(lens), pool.layout
    )
    cache["len"] = jnp.asarray(lens, jnp.int32)
    step_tok = jnp.asarray([7, 11], jnp.int32)

    ref_logits, ref_cache = model.decode_step(comp, step_tok, cache, pool.layout)
    with dispatch.force_mode("interpret"):
        fast_logits, fast_cache = model.decode_step(
            comp, step_tok, cache, pool.layout
        )
    np.testing.assert_allclose(
        np.asarray(fast_logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.05, rtol=0.05,
    )
    # the device-side cache mutation (page scatter) tracks the reference —
    # tolerance-level because later layers see fp-shifted residuals
    for a, b in zip(
        jax.tree_util.tree_leaves(ref_cache), jax.tree_util.tree_leaves(fast_cache)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=0.05, rtol=0.05,
        )
