"""Mesh-native serving: tensor-parallel decode over sharded params + caches.

Load-bearing guarantees (most of this file runs on an emulated 8-device
CPU mesh — ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the CI
``sharded`` job sets it, single-device runs skip those tests):

1. **Stream parity** — greedy token streams from a ``(data=2, model=4)``
   sharded engine are bit-identical to the single-device engine across
   {slab, paged} × {K=1, 4} × {dense, compressed} (the acceptance matrix),
   and across the windowed / recurrent / SSM arch families.
2. **No replicated weights** — the compressed path serves *sharded*: on a
   model-axis mesh no 2-D+ weight leaf (values or indices) is fully
   replicated, asserted on the live param arrays **and** on the compiled
   decode executable's input shardings.
3. **Degenerate 1×1 mesh** — a one-device mesh produces bit-identical
   streams to ``mesh=None`` (this one runs everywhere, tier-1 included).
4. ``make_local_mesh`` no longer drops remainder devices silently.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _trees(arch: str, **overrides):
    cfg = get_config(arch, smoke=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    sparse = recipe.export_sparse(params)
    return cfg, model, sparse, compress_params(sparse, recipe.sparsity)


def _prompts(cfg, lens, seed=100):
    return [
        [
            int(t)
            for t in jax.random.randint(
                jax.random.PRNGKey(seed + i), (n,), 0, cfg.vocab
            )
        ]
        for i, n in enumerate(lens)
    ]


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return (
        [res[u].tokens for u in uids],
        [res[u].finish_reason for u in uids],
    )


# ---------------------------------------------------------------------------
# the acceptance matrix: {slab, paged} × {K=1,4} × {dense, compressed}
# ---------------------------------------------------------------------------


@needs8
def test_greedy_streams_bit_identical_across_mesh():
    """(data=2, model=4) engine == single-device engine, whole matrix."""
    cfg, model, sparse, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    prompts = _prompts(cfg, [7, 4, 9])
    sps = [SamplingParams(max_new_tokens=8)] * 3
    paged = dict(num_pages=24, page_size=4)
    for tree in (sparse, comp):
        base = _stream(
            DecodeEngine(
                model, tree, max_batch=3, max_len=24, seed=3, donate=False
            ),
            prompts, sps,
        )
        for kw in (
            dict(),
            dict(steps_per_dispatch=4),
            dict(**paged),
            dict(steps_per_dispatch=4, **paged),
            # batched chunked prefill under the mesh (its executable has
            # its own in/out shardings): prompts 7 and 9 chunk at 4
            dict(prefill_chunk=4),
            dict(prefill_chunk=4, **paged),
        ):
            got = _stream(
                DecodeEngine(
                    model, tree, max_batch=3, max_len=24, seed=3, mesh=mesh,
                    **kw,
                ),
                prompts, sps,
            )
            assert got == base, (tree is comp, kw)


@needs8
@pytest.mark.parametrize(
    "arch", ["recurrentgemma-9b", "mamba2-2.7b", "starcoder2-3b"]
)
def test_mesh_parity_other_arch_families(arch):
    """Windowed attention, RG-LRU hybrid, and SSM lanes shard too (their
    O(1) recurrent states stay lane-sharded; windowed slabs seq-shard).

    f32 params: these archs' *untrained* bf16 logits have near-tie argmax
    margins that psum reassociation can flip — f32 pins the streams."""
    cfg, model, _, comp = _trees(arch, param_dtype="float32")
    mesh = make_local_mesh(4, data=2)
    prompts = _prompts(cfg, [5, 9])
    sps = [SamplingParams(max_new_tokens=6)] * 2
    base = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=24, seed=3, donate=False),
        prompts, sps,
    )
    for kw in (dict(), dict(num_pages=24, page_size=4)):
        got = _stream(
            DecodeEngine(
                model, comp, max_batch=2, max_len=24, seed=3, mesh=mesh, **kw
            ),
            prompts, sps,
        )
        assert got == base, (arch, kw)


@needs8
def test_mla_moe_decode_close_across_mesh():
    """MLA + MoE (deepseek): sharded decode logits match to fp tolerance.

    Exact stream equality is not asserted for MoE archs: top-k routing on
    an *untrained* model has near-tie margins that ulp-level psum
    reassociation can flip (discreteness amplification, not a sharding
    bug — forward logits agree to ~1e-6 below)."""
    import repro.models.model as M
    from repro.distributed.compressed_pspecs import serving_param_shardings

    cfg, model, sparse, _ = _trees(
        "deepseek-v2-lite-16b", param_dtype="float32"
    )
    mesh = make_local_mesh(4, data=2)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab)

    def fwd(p, batch):
        logits, _, _ = M.forward(p, cfg, batch, remat=False, want_cache=False)
        return logits

    psh = serving_param_shardings(mesh, sparse, cfg=cfg)
    l0 = jax.jit(fwd)(sparse, {"tokens": toks})
    l1 = jax.jit(fwd, in_shardings=(psh, None))(
        jax.device_put(sparse, psh), {"tokens": toks}
    )
    np.testing.assert_allclose(
        np.asarray(l0), np.asarray(l1), atol=1e-4, rtol=1e-4
    )


@needs8
def test_sampled_streams_match_across_mesh():
    """A temperature+top-k lane draws the same tokens on the mesh: the RNG
    thread (split per dispatch, inside the scan) is sharding-invariant."""
    cfg, model, _, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    prompts = _prompts(cfg, [7, 4])
    sps = [
        SamplingParams(max_new_tokens=6),
        SamplingParams(temperature=0.8, top_k=7, max_new_tokens=6),
    ]
    base = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=16, seed=5, donate=False),
        prompts, sps,
    )
    got = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=16, seed=5, mesh=mesh),
        prompts, sps,
    )
    assert got == base


# ---------------------------------------------------------------------------
# sharding inspection: the compressed artifact is served sharded
# ---------------------------------------------------------------------------


@needs8
def test_no_replicated_weight_leaf_on_live_executables():
    cfg, model, _, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    eng = DecodeEngine(
        model, comp, max_batch=2, max_len=16, seed=0, mesh=mesh,
        num_pages=16, page_size=4,
    )
    # live param arrays: every matmul-weight leaf (compressed
    # values/indices and dense alike) is actually distributed — only
    # small per-feature vectors (biases, norm scales) may replicate
    def is_vector_leaf(name: str) -> bool:
        return any(f in name for f in ("bias", "norm", "scale"))

    named = [
        ("/".join(str(getattr(p, "key", p)) for p in path), leaf)
        for path, leaf in jax.tree_util.tree_leaves_with_path(eng.params)
    ]
    leaves = [leaf for _, leaf in named]
    for name, leaf in named:
        if leaf.ndim >= 2 and not is_vector_leaf(name):
            assert not leaf.sharding.is_fully_replicated, (name, leaf.shape)
    rep = eng.sharding_report(include_hlo=True)
    # aggregate: one shard holds a strict fraction of the weight bytes
    assert rep["weight_bytes_per_shard"] * 2 < rep["weight_bytes"]
    assert rep["cache_bytes_per_shard"] * 2 < rep["cache_bytes"]
    # the *compiled decode executable* consumes them sharded, too
    flags = rep["decode_weight_inputs_replicated"]
    assert flags is not None and len(flags) == len(leaves)
    for (name, leaf), replicated in zip(named, flags):
        if leaf.ndim >= 2 and not is_vector_leaf(name):
            assert not replicated, (name, leaf.shape)
    # and the engine still serves correctly on those executables
    prompts = _prompts(cfg, [5, 3])
    sps = [SamplingParams(max_new_tokens=4)] * 2
    toks, reasons = _stream(eng, prompts, sps)
    assert all(len(t) == 4 for t in toks)


@needs8
def test_paged_pool_pages_sharded_tables_replicated():
    cfg, model, _, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    eng = DecodeEngine(
        model, comp, max_batch=2, max_len=16, seed=0, mesh=mesh,
        num_pages=16, page_size=4,
    )
    assert eng.layout.shards == 4
    # pool arrays: pages axis split over "model" (4 pages of 16 per shard)
    pool_leaves = [
        (path, leaf)
        for path, leaf in jax.tree_util.tree_leaves_with_path(eng.cache)
        if any(getattr(p, "key", None) in ("k", "v") for p in path)
    ]
    assert pool_leaves
    for path, leaf in pool_leaves:
        # scan-stacked body pools carry a leading (unsharded) layer axis
        ax = 1 if any(getattr(p, "key", None) == "body" for p in path) else 0
        shard_pages = leaf.sharding.shard_shape(leaf.shape)[ax]
        assert shard_pages * 4 == leaf.shape[ax], (path, leaf.shape)
    # tables: replicated (every shard resolves page addresses locally)
    sps = [SamplingParams(max_new_tokens=4)]
    _stream(eng, _prompts(cfg, [5]), sps)
    for t in eng.pool.device_tables().values():
        assert t.sharding.is_fully_replicated


# ---------------------------------------------------------------------------
# degenerate meshes + make_local_mesh (run everywhere)
# ---------------------------------------------------------------------------


def test_1x1_mesh_degenerates_bit_identically():
    cfg, model, _, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(1, data=1)
    prompts = _prompts(cfg, [7, 4])
    sps = [
        SamplingParams(max_new_tokens=5),
        SamplingParams(temperature=0.7, top_k=5, max_new_tokens=6),
    ]
    base = _stream(
        DecodeEngine(model, comp, max_batch=2, max_len=16, seed=5),
        prompts, sps,
    )
    for kw in (dict(), dict(num_pages=16, page_size=4, steps_per_dispatch=4)):
        got = _stream(
            DecodeEngine(
                model, comp, max_batch=2, max_len=16, seed=5, mesh=mesh, **kw
            ),
            prompts, sps,
        )
        assert got == base, kw


@needs8
def test_feature_kv_shard_parked_on_model_meshes():
    """kv_shard="feature" miscompiles under the SPMD partitioner (observed
    wrong streams) — engines and pools must refuse it on model-axis
    meshes instead of silently corrupting generations."""
    from repro.serving import PagedKVPool

    cfg, model, _, comp = _trees("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    with pytest.raises(NotImplementedError, match="feature"):
        DecodeEngine(
            model, comp, max_batch=2, max_len=16, mesh=mesh,
            kv_shard="feature",
        )
    with pytest.raises(NotImplementedError, match="feature"):
        PagedKVPool(
            model, max_batch=2, max_len=16, num_pages=16, page_size=4,
            mesh=mesh, kv_shard="feature",
        )
    # a pool and engine disagreeing on kv_shard is rejected too
    pool = PagedKVPool(
        model, max_batch=2, max_len=16, num_pages=16, page_size=4, mesh=mesh
    )
    with pytest.raises(ValueError, match="kv_shard"):
        DecodeEngine(
            model, comp, max_batch=2, max_len=16, mesh=mesh, kv_pool=pool,
            kv_shard="feature",
        )


def test_make_local_mesh_rejects_oversized_shapes():
    with pytest.raises(ValueError):
        make_local_mesh(N_DEV + 1)
    with pytest.raises(ValueError):
        make_local_mesh(1, data=N_DEV + 1)
    with pytest.raises(ValueError):
        make_local_mesh(0)


def test_make_local_mesh_explicit_shape():
    mesh = make_local_mesh(1, data=1)
    assert mesh.devices.shape == (1, 1)
    assert mesh.axis_names == ("data", "model")


@needs8
def test_make_local_mesh_warns_on_remainder():
    """8 devices, model=3: previously silently used 6 devices; now warns
    (and still builds the (2, 3) mesh over the first 6)."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_local_mesh(3)
    assert mesh.devices.shape == (2, 3)
    assert any("not divisible" in str(x.message) for x in w)
    # explicit shapes that fit exactly never warn
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_local_mesh(4, data=2)
    assert mesh.devices.shape == (2, 4)
    assert not w
