"""Per-architecture smoke tests (reduced configs, one fwd/train step on CPU)
and serving-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import (
    TransformerLM,
    frontend_dim,
    layer_plan,
    model_flops_per_token,
    param_count,
)

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = list(ASSIGNED_ARCHS) + ["gpt2-paper"]


def _batch(cfg, b=2, s=16, key=jax.random.PRNGKey(7)):
    batch = {"labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            key, (b, s, frontend_dim(cfg)), jnp.bfloat16
        )
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, _ = model.forward(params, batch, chunk=8)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, chunk=8), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize(
    "arch",
    [a for a in ALL_ARCHS if get_config(a).frontend == "none"],
)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    full, _, _ = model.forward(params, {"tokens": toks}, chunk=8)
    lp, cache = model.prefill(params, {"tokens": toks[:, :-1]}, max_len=16, chunk=8)
    ld, cache = model.decode_step(params, toks[:, -1], cache)
    np.testing.assert_allclose(
        np.asarray(lp, np.float32), np.asarray(full[:, -2], np.float32), atol=0.06
    )
    np.testing.assert_allclose(
        np.asarray(ld, np.float32), np.asarray(full[:, -1], np.float32), atol=0.06
    )
    assert int(cache["len"][0]) == 12


def test_layer_plans():
    rg = get_config("recurrentgemma-9b")
    plan = layer_plan(rg)
    assert plan.period == ("rec", "rec", "attn")
    assert plan.n_body == 12 and plan.tail == ("rec", "rec")
    ds = get_config("deepseek-v2-lite-16b")
    plan = layer_plan(ds)
    assert plan.head == ("attn:dense",) and plan.n_body == 26
    sc = get_config("starcoder2-3b")
    plan = layer_plan(sc)
    assert plan.n_body == 30 and not plan.head and not plan.tail


def test_param_counts_match_published_class():
    """Full configs land in the right parameter class (name plausibility)."""
    expected = {
        "starcoder2-3b": (2.5e9, 4e9),
        "qwen1.5-110b": (95e9, 125e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "command-r-plus-104b": (95e9, 115e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "dbrx-132b": (115e9, 145e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "musicgen-large": (1.5e9, 2.8e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "recurrentgemma-9b": (7.0e9, 11e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_below_total():
    from repro.models.model import active_param_count

    cfg = get_config("dbrx-132b")
    assert active_param_count(cfg) < param_count(cfg) * 0.4


def test_flops_per_token_scales_with_seq():
    cfg = get_config("starcoder2-3b")
    f1 = model_flops_per_token(cfg, 4096)
    f2 = model_flops_per_token(cfg, 32768)
    assert f2 > f1  # quadratic attention term grows
    mb = get_config("mamba2-2.7b")
    assert model_flops_per_token(mb, 4096) == model_flops_per_token(mb, 32768)


def test_local_window_attention_masks_far_tokens():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 24), 0, cfg.vocab)
    # changing a token far outside the window must not change the last logits
    # (window=16 in smoke config; distance 20 > window and no recurrent path
    # would hide it only if attention leaked) — recurrent layers DO carry
    # state, so instead check window masking directly on the attention layer.
    from repro.models.layers import chunked_attention

    q = jax.random.normal(jax.random.PRNGKey(5), (1, 24, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 24, 1, 8))
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 24, 1, 8))
    out1 = chunked_attention(q, k, v, causal=True, window=4, chunk=8)
    k2 = k.at[:, 0].set(99.0)
    v2 = v.at[:, 0].set(99.0)
    out2 = chunked_attention(q, k2, v2, causal=True, window=4, chunk=8)
    np.testing.assert_allclose(
        np.asarray(out1[:, 10:]), np.asarray(out2[:, 10:]), atol=1e-5
    )


def test_mrope_position_streams_differ():
    from repro.models.layers import apply_mrope, apply_rope

    x = jax.random.normal(jax.random.PRNGKey(8), (1, 6, 2, 16))
    pos_text = jnp.broadcast_to(jnp.arange(6)[None, :, None], (1, 6, 3))
    same = apply_mrope(x, pos_text)
    pos_img = pos_text.at[..., 1].set(jnp.arange(6)[None] * 3)
    diff = apply_mrope(x, pos_img)
    assert not np.allclose(np.asarray(same), np.asarray(diff))
