import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Long single-process test runs exhaust XLA's JIT dylib space; clearing
    compiled-executable caches between modules keeps the suite stable."""
    yield
    jax.clear_caches()


# -- optional hypothesis shim -------------------------------------------------
# hypothesis is an optional dependency: test modules do
# ``from conftest import given, settings, st`` and their property sweeps
# become skipped tests when it is absent, while fixed-case tests keep running.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:

    def _skip_without_hypothesis(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    given = settings = _skip_without_hypothesis

    class st:  # placeholder strategies (never evaluated)
        sampled_from = integers = staticmethod(lambda *_a, **_k: None)
