import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Long single-process test runs exhaust XLA's JIT dylib space; clearing
    compiled-executable caches between modules keeps the suite stable."""
    yield
    jax.clear_caches()
