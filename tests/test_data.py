"""Data pipeline: determinism, restartability, prefetch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataIterator, IteratorState
from repro.data.synthetic import SyntheticLMDataset, SyntheticTask

jax.config.update("jax_platform_name", "cpu")


def test_lm_batches_are_pure_functions_of_step():
    ds = SyntheticLMDataset(vocab=64, seq_len=32, seed=5)
    b1 = ds.batch(7, 4)
    b2 = ds.batch(7, 4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = ds.batch(8, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(vocab=64, seq_len=16, seed=1)
    b = ds.batch(0, 2)
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )


def test_lm_is_learnable_markov():
    """The chain must be lower-entropy than uniform (a model CAN learn it)."""
    ds = SyntheticLMDataset(vocab=64, seq_len=256, seed=3, n_states=8)
    b = ds.batch(0, 8)
    toks = np.asarray(b["tokens"]).ravel()
    # bigram conditional entropy << uniform entropy
    joint = np.zeros((64, 64))
    for a, b_ in zip(toks[:-1], toks[1:]):
        joint[a, b_] += 1
    p = joint / joint.sum()
    pa = p.sum(1, keepdims=True)
    cond = p / np.maximum(pa, 1e-12)
    h = -np.nansum(p * np.log(np.where(cond > 0, cond, 1.0)))
    assert h < 0.7 * np.log(64)


def test_iterator_state_roundtrip():
    ds = SyntheticLMDataset(vocab=32, seq_len=8, seed=0)
    it = DataIterator(batch_fn=ds.batch, batch_size=2, prefetch=0)
    a = next(it)
    b = next(it)
    st = it.get_state()
    c = next(it)
    it2 = DataIterator(batch_fn=ds.batch, batch_size=2, prefetch=0)
    it2.set_state(st)
    c2 = next(it2)
    np.testing.assert_array_equal(np.asarray(c["tokens"]), np.asarray(c2["tokens"]))


def test_prefetch_thread_matches_sync():
    ds = SyntheticLMDataset(vocab=32, seq_len=8, seed=9)
    sync = DataIterator(batch_fn=ds.batch, batch_size=2, prefetch=0)
    thr = DataIterator(batch_fn=ds.batch, batch_size=2, prefetch=2)
    for _ in range(5):
        a, b = next(sync), next(thr)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    thr.close()


def test_teacher_is_exactly_nm_sparse():
    task = SyntheticTask(n=2, m=4, seed=0)
    t = task.teacher()
    w = np.asarray(t["w1"]).T.reshape(task.hidden, -1, 4)
    assert ((w != 0).sum(-1) <= 2).all()
