"""End-to-end behaviour tests: the paper's pipeline on a real (small) LM.

Covers: STEP trains a GPT-2-family model on the synthetic LM task, the mask
learning engages after AutoSwitch fires, the exported model is exactly N:M
sparse, the compressed serving path reproduces dense-masked logits, and the
recipe comparison reproduces the paper's *ordering* (STEP >= SR-STE on Adam).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.data import DataIterator, SyntheticLMDataset
from repro.models.model import TransformerLM
from repro.train import Trainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


CFG = get_config("gpt2-paper", smoke=True)
DS = SyntheticLMDataset(vocab=CFG.vocab, seq_len=32, seed=42, n_states=16)
MODEL = TransformerLM(CFG)


def _loss(p, batch):
    loss, m = MODEL.loss(p, batch, chunk=16)
    return loss, m


def _train(kind, steps=140, seed=0, **recipe_kw):
    recipe = core.make_recipe(
        kind, core.SparsityConfig(default=core.NMSparsity(2, 4)), **recipe_kw
    )
    scfg = core.StepConfig(
        learning_rate=3e-3,
        b2=0.98,
        autoswitch=core.AutoSwitchConfig(eps=2e-5, window=25, t_min=25, t_max=70),
    )
    data = DataIterator(batch_fn=DS.batch, batch_size=8, prefetch=0)
    tr = Trainer(_loss, recipe, scfg, data,
                 TrainerConfig(total_steps=steps, log_every=0, ckpt_every=0))
    params = MODEL.init(jax.random.PRNGKey(seed))
    state, _ = tr.run(params)
    sparse = recipe.export_sparse(state.params)
    eval_batch = DS.batch(99_999, 16)
    loss, _ = MODEL.loss(sparse, eval_batch, chunk=16)
    return float(loss), state, recipe


def test_step_trains_lm_and_masks_engage():
    loss, state, recipe = _train("step")
    assert bool(state.opt.phase2), "AutoSwitch never fired"
    assert loss < 4.0, f"sparse eval loss {loss} did not improve over ~ln(256)=5.5"
    # exported weights are exactly 2:4 on maskable tensors
    masked = np.asarray(recipe.export_sparse(state.params)["body"]["sb_0"]["attn"]["wq"][0], np.float32)
    groups = masked.reshape(-1, 4, masked.shape[-1]).swapaxes(1, 2)
    assert ((groups != 0).sum(-1) <= 2).all()


def test_dense_beats_nothing_and_step_close_to_dense():
    dense_loss, _, _ = _train("dense")
    step_loss, _, _ = _train("step")
    assert step_loss < dense_loss + 1.2  # sparse within striking distance


def test_recipe_ordering_matches_paper_on_adam():
    """Paper's headline: with Adam, STEP mitigates the SR-STE drop.
    We assert STEP <= SR-STE + small tolerance on the same budget."""
    sr_loss, _, _ = _train("sr_ste")
    step_loss, _, _ = _train("step")
    assert step_loss <= sr_loss + 0.25, (step_loss, sr_loss)


def test_compressed_serving_matches_masked_dense():
    _, state, recipe = _train("step", steps=60)
    sparse = recipe.export_sparse(state.params)
    from repro.sparse_infer import compress_params, decompress_params

    comp = compress_params(sparse, recipe.sparsity)
    back = decompress_params(comp)
    batch = DS.batch(5, 2)
    l1, _, _ = MODEL.forward(sparse, batch, chunk=16)
    l2, _, _ = MODEL.forward(back, batch, chunk=16)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=1e-3
    )


def test_greedy_decode_runs():
    params = MODEL.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, CFG.vocab)
    logits, cache = MODEL.prefill(params, {"tokens": toks}, max_len=16, chunk=8)
    outs = []
    tok = jnp.argmax(logits, -1)
    for _ in range(6):
        logits, cache = MODEL.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)
        outs.append(tok)
    assert len(outs) == 6 and int(cache["len"][0]) == 14
