"""Prefix caching: refcounted shared pages, COW forks, radix index, int8 KV.

Load-bearing guarantees of the PR-7 serving stack:

1. **Pool conservation** — under randomized churn (admissions, shared-
   prefix admissions, decode growth with COW forks, preemptive releases)
   ``free + used == num_pages`` holds at every step, every mapped page
   carries a reference, and after everything releases the pool is byte-
   for-byte empty (zero leaks, all refcounts zero).
2. **Fork ≡ cold** — a prefix-hit admission (pages mapped from the radix
   index, only the tail prefilled) produces the *same greedy stream* as a
   cold admission of the identical prompt, for full attention and MLA,
   single-device and on a (2, 4) mesh.
3. **int8 pages** — per-(page, slot) symmetric int8 with f16-stored /
   f32-compute scales: kernel outputs match fp pages to quantization
   tolerance, streams keep the same finish profile (stop decisions and
   lengths never change), and int8 fork-vs-cold parity is bit-exact
   (same codes written ⇒ same codes read).
4. **Oracle** — ``paged_attn_ref`` (dense gather + one softmax) agrees
   with the XLA gathered route and the Pallas kernel (interpret) on both
   fp and int8 pages.
"""
import random
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.kernels.paged_attn import paged_attn_pallas, paged_attn_xla
from repro.kernels.ref import paged_attn_ref
from repro.launch.mesh import make_local_mesh
from repro.models.cache import PagedLayout
from repro.serving import DecodeEngine, PagedKVPool, SamplingParams
from repro.serving.prefix_cache import PrefixIndex
from repro.models.model import TransformerLM
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _compressed(arch: str, seed=0):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    return cfg, model, compress_params(recipe.export_sparse(params), recipe.sparsity)


def _rand_prompt(seed, n, vocab):
    return [int(t) for t in jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab)]


def _waves(eng, waves):
    """Submit + drain wave by wave (so later waves can hit pages the
    earlier waves indexed); returns ([tokens...], [finish_reason...])."""
    toks, reasons = [], []
    for prompts, sps in waves:
        uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
        res = eng.run()
        toks += [res[u].tokens for u in uids]
        reasons += [res[u].finish_reason for u in uids]
    return toks, reasons


def _shared_waves(cfg, head_len=12, tails=(3, 5, 2), gen=5, seed=500):
    """Wave 1 = one cold prompt; wave 2 = len(tails)-1 prompts sharing its
    head.  All greedy."""
    head = _rand_prompt(seed, head_len, cfg.vocab)
    prompts = [head + _rand_prompt(seed + 1 + i, t, cfg.vocab)
               for i, t in enumerate(tails)]
    sp = SamplingParams(max_new_tokens=gen)
    return [([prompts[0]], [sp]), (prompts[1:], [sp] * (len(prompts) - 1))]


# ---------------------------------------------------------------------------
# pool conservation under randomized churn
# ---------------------------------------------------------------------------


def _check_conserved(pool):
    n = pool.layout.num_pages
    assert pool.free_pages + pool.used_pages == n
    assert pool.used_pages == int((pool._ref > 0).sum())
    assert pool.shared_pages == int((pool._ref > 1).sum())
    for lane_map in pool._full_pages:
        for pid in lane_map.values():
            assert pool._ref[pid] > 0, f"mapped page {pid} has no reference"


def test_pool_conservation_random_churn():
    """300 random ops — admissions (some forking a live lane's prefix),
    decode growth (COW on shared pages), preemptive releases, periodic
    pending-copy drains — never break ``free + used == num_pages``; at
    the end the pool is fully free with every refcount at zero."""
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=4, max_len=32, num_pages=24, page_size=4)
    rng = random.Random(7)
    lens: dict[int, int] = {}  # lane -> cached length (next write pos)

    for _ in range(300):
        op = rng.random()
        idle = [l for l in range(pool.max_batch) if l not in lens]
        live = sorted(lens)
        if op < 0.40 and idle:
            lane = rng.choice(idle)
            plen = rng.randint(2, 16)
            shared, shared_len = (), 0
            donors = [l for l in live if lens[l] >= 2]
            if donors and rng.random() < 0.6:
                d = rng.choice(donors)
                shared_len = rng.randint(1, min(lens[d], plen) - 1)
                full, tail = pool.prompt_pages(d, shared_len)
                shared = tuple(full + ([tail] if tail is not None else []))
            if pool.alloc_prefill(lane, plen, shared_full=shared,
                                  shared_len=shared_len):
                lens[lane] = plen
        elif op < 0.75 and live:
            lane = rng.choice(live)
            k = rng.randint(1, 3)
            if lens[lane] + k > pool.max_len:
                pool.release(lane)
                del lens[lane]
            elif pool.ensure_steps(lane, lens[lane], k):
                lens[lane] += k
            else:  # pool full: all-or-nothing, preempt the lane
                pool.release(lane)
                del lens[lane]
        elif op < 0.9 and live:
            lane = rng.choice(live)
            pool.release(lane)
            del lens[lane]
        elif pool.pending_copies:
            pool.cache = pool.apply_pending(pool.cache)
            assert not pool.pending_copies
        _check_conserved(pool)

    for lane in list(lens):
        pool.release(lane)
    pool.cache = pool.apply_pending(pool.cache)
    assert pool.free_pages == pool.layout.num_pages
    assert pool.used_pages == 0
    assert (pool._ref == 0).all()
    assert pool.cow_copies > 0  # the churn actually exercised COW


def test_cow_pins_source_until_copy_lands():
    """A forked page's source stays allocated (pending-copy pin) until
    ``apply_pending`` materializes the copy — even if every other holder
    releases first."""
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=2, max_len=32, num_pages=12, page_size=4)
    assert pool.alloc_prefill(0, 8)  # pages 0..1 + decode page
    full, _ = pool.prompt_pages(0, 6)  # 1 full page + mid-page boundary
    assert pool.alloc_prefill(1, 9, shared_full=tuple(
        full + [pool._full_pages[0][1]]), shared_len=6)
    assert pool.cow_copies == 1 and len(pool.pending_copies) == 1
    src, dst = pool.pending_copies[0]
    pool.release(0)
    pool.release(1)
    assert pool._ref[src] == 1  # only the pending pin keeps it alive
    pool.cache = pool.apply_pending(pool.cache)
    assert pool._ref[src] == 0 and pool._ref[dst] == 0
    assert pool.free_pages == pool.layout.num_pages


# ---------------------------------------------------------------------------
# radix index semantics
# ---------------------------------------------------------------------------


def test_prefix_index_match_insert_evict():
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=2, max_len=32, num_pages=16, page_size=4)
    idx = PrefixIndex(pool, 4)
    prompt = list(range(10))  # 2 full pages + a 2-token tail
    assert pool.alloc_prefill(0, 10)
    full, tail = pool.prompt_pages(0, 10)
    idx.insert(prompt, full, tail, 2)
    assert idx.pages == 3  # 2 full + 1 partial, each holding a pool ref
    assert all(pool._ref[p] == 2 for p in full)

    # exact full-page + partial match (capped at len-1 so the tail's 2nd
    # token can never be the whole remaining prompt)
    m, pids = idx.match(prompt + [99])
    assert m == 10 and list(pids) == full + [tail]
    # diverging second page: only the first full page matches
    m, pids = idx.match(list(range(4)) + [77, 78, 79, 80, 81])
    assert m == 4 and list(pids) == full[:1]
    # the cap: matching may cover at most len(prompt) - 1 tokens
    m, _ = idx.match(list(range(8)))
    assert m == 4
    # no match at all
    m, pids = idx.match([55, 56, 57, 58, 59])
    assert (m, pids) == (0, ())

    # duplicate insert is a no-op (first entry keeps its single ref)
    idx.insert(prompt, full, tail, 2)
    assert idx.pages == 3 and all(pool._ref[p] == 2 for p in full)

    # release the producing lane; indexed pages stay resident
    pool.release(0)
    assert all(pool._ref[p] == 1 for p in full)
    used = pool.used_pages
    freed = idx.evict(used)
    assert freed == used and idx.pages == 0
    assert pool.free_pages == pool.layout.num_pages
    m, pids = idx.match(prompt + [99])
    assert (m, pids) == (0, ())


def test_prefix_index_partial_dominated_by_longer():
    """Inserting a longer partial for the same node evicts the shorter one
    it extends (single ref moves over, no leak)."""
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=2, max_len=32, num_pages=16, page_size=4)
    idx = PrefixIndex(pool, 4)
    assert pool.alloc_prefill(0, 2)
    _, t0 = pool.prompt_pages(0, 2)
    idx.insert([1, 2], [], t0, 2)
    assert pool.alloc_prefill(1, 3)
    _, t1 = pool.prompt_pages(1, 3)
    idx.insert([1, 2, 3], [], t1, 3)
    assert idx.pages == 1  # the 3-token partial dominated the 2-token one
    m, pids = idx.match([1, 2, 3, 9])
    assert m == 3 and pids == (t1,)
    pool.release(0)
    pool.release(1)
    idx.clear()
    assert pool.free_pages == pool.layout.num_pages


# ---------------------------------------------------------------------------
# fork ≡ cold: engine-level stream parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,ps", [("gpt2-paper", 4), ("deepseek-v2-lite-16b", 4)])
def test_prefix_hit_stream_matches_cold(arch, ps):
    """Wave 2 shares wave 1's 12-token head: with the index on it admits
    via mapped pages + tail chunk-prefill, and its greedy streams are
    bit-identical to the index-off engine's; afterwards clearing the
    index leaves zero pages behind."""
    cfg, model, comp = _compressed(arch)
    waves = _shared_waves(cfg)
    kw = dict(max_batch=2, max_len=32, num_pages=32, page_size=ps, seed=3)
    cold = _waves(DecodeEngine(model, comp, **kw), waves)
    eng = DecodeEngine(model, comp, prefix_cache=True, **kw)
    warm = _waves(eng, waves)
    assert warm == cold
    assert eng.prefix_hits == 2  # both wave-2 requests reused the head
    assert eng.prefix_hit_tokens == 2 * 12
    st = eng.stats()
    assert st["prefix_hits"] == 2 and st["cow_copies"] == eng.pool.cow_copies
    # zero leaks: all lanes done, so the index holds every live page
    eng._prefix.clear()
    assert eng.pool.free_pages == eng.pool.layout.num_pages
    assert (eng.pool._ref == 0).all()


def test_prefix_hit_parity_with_chunked_prefill_and_k_steps():
    """Prefix cache composes with the fused-decode / chunked-prefill
    engine configuration (the hit tail drains through the same chunk
    lane)."""
    cfg, model, comp = _compressed("gpt2-paper")
    waves = _shared_waves(cfg, head_len=11, tails=(4, 6), gen=4)
    kw = dict(max_batch=2, max_len=32, num_pages=32, page_size=4, seed=0,
              steps_per_dispatch=4, prefill_chunk=4)
    cold = _waves(DecodeEngine(model, comp, **kw), waves)
    eng = DecodeEngine(model, comp, prefix_cache=True, **kw)
    assert _waves(eng, waves) == cold
    assert eng.prefix_hits == 1


def test_prefix_cache_refused_without_full_table():
    """Windowed layouts evict pages, so the engine warns and disables the
    index instead of serving stale prefixes."""
    cfg, model, comp = _compressed("recurrentgemma-9b")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = DecodeEngine(
            model, comp, max_batch=1, max_len=24, num_pages=16, page_size=4,
            prefix_cache=True,
        )
    assert eng._prefix is None
    assert any("prefix" in str(x.message).lower() for x in w)
    # slab engines (no pool at all) get the same guard
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        slab = DecodeEngine(model, comp, max_batch=1, max_len=24,
                            prefix_cache=True)
    assert slab._prefix is None
    assert any("prefix" in str(x.message).lower() for x in w)


@needs8
def test_prefix_hit_stream_matches_cold_on_mesh():
    """Fork ≡ cold holds on a (2, 4) mesh (sharded pool, shard_map or
    gathered kernel route underneath)."""
    cfg, model, comp = _compressed("gpt2-paper")
    mesh = make_local_mesh(4, data=2)
    waves = _shared_waves(cfg)
    kw = dict(max_batch=2, max_len=32, num_pages=32, page_size=4, seed=3)
    cold = _waves(DecodeEngine(model, comp, **kw), waves)
    eng = DecodeEngine(model, comp, mesh=mesh, prefix_cache=True, **kw)
    assert _waves(eng, waves) == cold
    assert eng.prefix_hits == 2


# ---------------------------------------------------------------------------
# int8 KV pages
# ---------------------------------------------------------------------------


def test_quant_roundtrip_error_bound():
    """Per-token absmax int8 with the f16 scale round-trip: stored scales
    are f16, codes never overflow (the f16-rounded scale is within 5e-4
    relative, far under the 1/254 that could push |code| past 127), and
    the reconstruction error is <= scale/2 elementwise."""
    lo = PagedLayout(page_size=4, num_pages=8, max_len=32, quant=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 2, 16)) * 3.0
    q, s = lo._quant(x, 2)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16
    assert s.shape == (8, 4)
    assert int(jnp.max(jnp.abs(q))) <= 127
    xr = lo.dequant(q, s)
    err = np.abs(np.asarray(xr) - np.asarray(x, np.float32))
    bound = 0.5 * np.asarray(s, np.float32)[..., None, None] + 1e-6
    assert (err <= bound).all()
    # all-zero tokens stay exactly zero (clamp floor, no NaN/Inf)
    q0, s0 = lo._quant(jnp.zeros((2, 4, 2, 16)), 2)
    assert (np.asarray(lo.dequant(q0, s0)) == 0).all()


def test_int8_kernel_matches_fp_within_tolerance():
    """Quantize fp pages, run the gathered XLA route with scales: output
    stays within int8 quantization tolerance of the fp-page output, and
    the Pallas kernel (interpret) agrees with the XLA route on the same
    int8 operands to fp32 accuracy."""
    b, hkv, g, d, ps, num_pages, n_slots = 3, 2, 2, 16, 4, 10, 4
    lengths = jnp.asarray([3, 9, 14], jnp.int32)
    lo = PagedLayout(page_size=ps, num_pages=num_pages, max_len=16, quant=True)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hkv, g, d))
    k_pages = jax.random.normal(jax.random.PRNGKey(1), (num_pages, ps, hkv, d))
    v_pages = jax.random.normal(jax.random.PRNGKey(2), (num_pages, ps, hkv, d))
    t = np.full((b, n_slots), num_pages, np.int32)
    nxt = 0
    for i, ln in enumerate([3, 9, 14]):
        for pg in range(-(-ln // ps)):
            t[i, pg] = nxt
            nxt += 1
    tables = jnp.asarray(t)
    scale = d ** -0.5
    kq, ks = lo._quant(k_pages, 2)
    vq, vs = lo._quant(v_pages, 2)

    y_fp = paged_attn_xla(q, k_pages, v_pages, tables, lengths, scale=scale)
    y_q = paged_attn_xla(
        q, kq, vq, tables, lengths, scale=scale, k_scale=ks, v_scale=vs
    )
    np.testing.assert_allclose(
        np.asarray(y_q), np.asarray(y_fp), atol=5e-2, rtol=5e-2
    )
    y_pl = paged_attn_pallas(
        q, kq, vq, tables, lengths, scale=scale, k_scale=ks, v_scale=vs,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_pl), np.asarray(y_q), atol=1e-5, rtol=1e-5
    )
    # and both agree with the dense oracle on the identical int8 operands
    y_ref = paged_attn_ref(
        q, kq, vq, tables, lengths, scale=scale, k_scale=ks, v_scale=vs
    )
    np.testing.assert_allclose(
        np.asarray(y_q), np.asarray(y_ref), atol=1e-5, rtol=1e-5
    )


def test_paged_attn_ref_oracle_fp():
    """fp pages: XLA gathered route and Pallas interpret both match the
    dense gather-everything oracle."""
    b, hkv, g, d, ps, num_pages, n_slots = 4, 2, 3, 16, 4, 12, 6
    lengths = jnp.asarray([1, 7, 21, 0], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hkv, g, d))
    k_pages = jax.random.normal(jax.random.PRNGKey(1), (num_pages, ps, hkv, d))
    v_pages = jax.random.normal(jax.random.PRNGKey(2), (num_pages, ps, hkv, d))
    t = np.full((b, n_slots), num_pages, np.int32)
    nxt = 0
    for i, ln in enumerate([1, 7, 21, 0]):
        for pg in range(-(-ln // ps)):
            t[i, pg] = nxt % num_pages
            nxt += 1
    tables = jnp.asarray(t)
    scale = d ** -0.5
    y_ref = paged_attn_ref(q, k_pages, v_pages, tables, lengths, scale=scale)
    y_x = paged_attn_xla(q, k_pages, v_pages, tables, lengths, scale=scale)
    y_k = paged_attn_pallas(
        q, k_pages, v_pages, tables, lengths, scale=scale, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    assert float(jnp.max(jnp.abs(y_ref[3]))) == 0.0  # idle lane exact zeros


@pytest.mark.parametrize("arch", ["gpt2-paper", "deepseek-v2-lite-16b"])
def test_int8_stream_same_finish_profile(arch):
    """int8 pages may perturb near-tie greedy picks on untrained weights,
    but the finish *profile* — reasons and lengths — must match fp, and
    the per-request first chunk of tokens tracks fp closely."""
    cfg, model, comp = _compressed(arch)
    prompts = [_rand_prompt(700 + r, 5 + 2 * r, cfg.vocab) for r in range(3)]
    sps = [SamplingParams(max_new_tokens=6)] * 3
    kw = dict(max_batch=2, max_len=32, num_pages=32, page_size=4, seed=0)

    def run(quant):
        eng = DecodeEngine(model, comp, kv_quant=quant, **kw)
        uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
        res = eng.run()
        return [(len(res[u].tokens), res[u].finish_reason) for u in uids]

    assert run(True) == run(False)


def test_int8_fork_vs_cold_bit_exact():
    """Within int8, a prefix hit is bit-exact vs cold: the hit lane reads
    the very codes the cold lane would have written (same inputs ⇒ same
    quantization), so determinism survives quantization."""
    cfg, model, comp = _compressed("gpt2-paper")
    waves = _shared_waves(cfg, seed=900)
    kw = dict(max_batch=2, max_len=32, num_pages=32, page_size=4, seed=3,
              kv_quant=True)
    cold = _waves(DecodeEngine(model, comp, **kw), waves)
    eng = DecodeEngine(model, comp, prefix_cache=True, **kw)
    assert _waves(eng, waves) == cold
    assert eng.prefix_hits == 2
    assert eng.pool.layout.quant


def test_engine_rejects_quant_mismatch():
    """Handing the engine a pre-built fp pool while asking kv_quant=True
    must fail loudly (silent fp fallback would fake the HBM win)."""
    _, model, comp = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=1, max_len=16, num_pages=8, page_size=4)
    with pytest.raises(ValueError):
        DecodeEngine(
            model, comp, max_batch=1, max_len=16, kv_pool=pool, kv_quant=True
        )
