"""Recipe mechanics: dense / STE / SR-STE / ASP / Decay / STEP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core

jax.config.update("jax_platform_name", "cpu")

SCFG = core.SparsityConfig(default=core.NMSparsity(2, 4))


def _params():
    k = jax.random.PRNGKey(0)
    return {
        "layer1": {"w": jax.random.normal(k, (16, 8)), "bias": jnp.zeros((8,))},
        "embed": {"tok_embed": jax.random.normal(k, (32, 16))},
    }


def _run_masks(recipe, params, steps, phase2_at=None):
    st = recipe.init_state(params)
    out = []
    for t in range(steps):
        phase2 = jnp.asarray(phase2_at is not None and t >= phase2_at)
        mask, active, st = recipe.masks_for_step(params, st, phase2)
        out.append((mask, bool(active)))
    return out, st


def test_dense_never_masks():
    recipe = core.make_recipe("dense", SCFG)
    out, _ = _run_masks(recipe, _params(), 3)
    assert not any(a for _, a in out)


def test_ste_always_masks_weights_not_bias_or_embed():
    recipe = core.make_recipe("ste", SCFG)
    out, _ = _run_masks(recipe, _params(), 2)
    mask, active = out[0]
    assert active
    assert float(mask["layer1"]["w"].mean()) == 0.5
    assert (mask["layer1"]["bias"] == 1).all()  # 1-D excluded
    assert (mask["embed"]["tok_embed"] == 1).all()  # embeddings excluded


def test_step_masks_only_in_phase2():
    recipe = core.make_recipe("step", SCFG)
    out, _ = _run_masks(recipe, _params(), 4, phase2_at=2)
    assert [a for _, a in out] == [False, False, True, True]
    assert (out[0][0]["layer1"]["w"] == 1).all()
    assert float(out[2][0]["layer1"]["w"].mean()) == 0.5


def test_asp_prunes_once_and_freezes():
    params = _params()
    recipe = core.make_recipe("asp", SCFG, prune_at=2)
    st = recipe.init_state(params)
    masks = []
    for t in range(5):
        mask, active, st = recipe.masks_for_step(params, st, jnp.asarray(False))
        masks.append((np.asarray(mask["layer1"]["w"]), bool(active)))
        params = jax.tree_util.tree_map(lambda p: p * 1.1, params)  # drift
    assert [a for _, a in masks] == [False, False, True, True, True]
    np.testing.assert_array_equal(masks[2][0], masks[4][0])  # frozen


def test_decay_schedule_tightens():
    recipe = core.make_recipe("decay", SCFG, dense_until=2, decay_interval=2)
    params = _params()
    st = recipe.init_state(params)
    densities = []
    for t in range(10):
        mask, active, st = recipe.masks_for_step(params, st, jnp.asarray(False))
        densities.append(float(mask["layer1"]["w"].mean()))
    assert densities[0] == 1.0 and densities[1] == 1.0  # dense phase
    # then 3:4 -> 2:4 (target floor) and never below target
    assert densities[2] == 0.75
    assert densities[4] == 0.5
    assert min(densities[4:]) == 0.5


def test_sr_ste_grad_term_applied():
    recipe = core.make_recipe("sr_ste", SCFG, sr_lambda=0.1)
    params = _params()
    st = recipe.init_state(params)
    mask, active, st = recipe.masks_for_step(params, st, jnp.asarray(False))
    g0 = jax.tree_util.tree_map(jnp.zeros_like, params)
    g = recipe.grad_postprocess(g0, params, mask, active)
    w, mw = np.asarray(params["layer1"]["w"]), np.asarray(mask["layer1"]["w"])
    np.testing.assert_allclose(np.asarray(g["layer1"]["w"]), 0.1 * (1 - mw) * w, rtol=1e-6)
    # plain ste adds nothing
    recipe2 = core.make_recipe("ste", SCFG)
    g2 = recipe2.grad_postprocess(g0, params, mask, active)
    assert (np.asarray(g2["layer1"]["w"]) == 0).all()


def test_export_sparse_is_exactly_nm():
    recipe = core.make_recipe("step", SCFG)
    params = _params()
    sp = recipe.export_sparse(params)
    w = np.asarray(sp["layer1"]["w"]).T.reshape(8, 4, 4)  # groups along axis 0
    nz = (w != 0).sum(-1)
    assert (nz == 2).all()


def test_layerwise_patterns_override_default():
    cfg = core.SparsityConfig(
        default=core.NMSparsity(2, 4),
        layer_patterns=((r"layer1/w", core.NMSparsity(1, 4)),),
    )
    recipe = core.make_recipe("ste", cfg)
    out, _ = _run_masks(recipe, _params(), 1)
    assert float(out[0][0]["layer1"]["w"].mean()) == 0.25


def test_domino_search_meets_budget():
    params = {
        f"blk{i}": {"w": jax.random.normal(jax.random.PRNGKey(i), (32, 16)) * (i + 1)}
        for i in range(4)
    }
    cfg = core.domino_search(params, SCFG, m=8, target_density=0.5)
    recipe = core.make_recipe("ste", cfg)
    st = recipe.init_state(params)
    mask, _, _ = recipe.masks_for_step(params, st, jnp.asarray(False))
    density = float(
        sum(m.sum() for m in jax.tree_util.tree_leaves(mask))
        / sum(m.size for m in jax.tree_util.tree_leaves(mask))
    )
    assert density <= 0.55
    # layers with larger weights should keep more
    ratios = core.assigned_ratios(cfg)
    ns = [int(v.split(":")[0]) for k, v in sorted(ratios.items())]
    assert ns[-1] >= ns[0]


def test_sparsity_report():
    rep = core.sparsity_report(_params(), SCFG)
    assert rep["maskable_params"] == 16 * 8
    assert 0 < rep["maskable_fraction"] < 1
    assert rep["per_leaf"]["layer1/w"] == "2:4"
    assert rep["per_leaf"]["embed/tok_embed"] == "dense"
