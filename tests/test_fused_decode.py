"""Zero-copy fused decode loop: donation, K-steps-per-dispatch, chunked
prefill, incremental page-table sync.

Load-bearing guarantees of the dispatch-boundary engine:

1. **Stream invariance** — greedy *and* sampled token streams are
   bit-identical across {slab, paged} × {K=1, K=4} × {donated, undonated}:
   donation only removes copies, and the K-step on-device scan consumes
   the same per-step RNG splits and runs the same per-step math as the
   host-driven loop.
2. **On-device stop detection** — a lane that emits EOS or exhausts its
   budget mid-scan freezes on device and the host replay of the ``(K, B)``
   token block finishes it identically to the K=1 engine.
3. **Preemption at dispatch boundaries** — ``ensure_steps`` reserves all K
   writes up front, so an undersized pool preempts between dispatches
   (never mid-scan) and resumed requests reproduce the un-preempted
   stream.
4. **Chunked prefill** — a long prompt absorbed in fixed-size chunks
   interleaved with decode dispatches yields the same greedy streams as
   the monolithic prefill, and recurrent/windowed archs gate it off.
5. **Incremental table sync** — one full upload, then dirty-row scatters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, PagedKVPool, SamplingParams
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")


def _compressed(arch: str, seed=0):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    return cfg, model, compress_params(recipe.export_sparse(params), recipe.sparsity)


CFG, MODEL, COMP = _compressed("gpt2-paper")


def _rand_prompt(seed, n, vocab=None):
    vocab = vocab or CFG.vocab
    return [int(t) for t in jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, vocab)]


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return (
        [res[u].tokens for u in uids],
        [res[u].finish_reason for u in uids],
    )


# ---------------------------------------------------------------------------
# the full invariance matrix: layout × K × donation, greedy + sampled lanes
# ---------------------------------------------------------------------------


def test_stream_invariance_matrix():
    """{slab, paged} × {K=1, K=4} × {donated, undonated} produce identical
    greedy *and* sampled streams.  All requests are admitted upfront so
    every variant runs the same schedule (mid-run admission shifts which
    step index a sampled lane draws from — greedy alone would not catch a
    broken RNG thread)."""
    prompts = [_rand_prompt(100 + r, 3 + 3 * r) for r in range(4)]
    sps = [SamplingParams(max_new_tokens=4 + 2 * r) for r in range(4)]
    sps[1] = SamplingParams(temperature=0.8, top_k=7, max_new_tokens=6)

    def run(**kw):
        eng = DecodeEngine(MODEL, COMP, max_batch=4, max_len=32, seed=5, **kw)
        return _stream(eng, prompts, sps), eng

    base, _ = run(donate=False, steps_per_dispatch=1)
    paged = dict(num_pages=24, page_size=4)
    for kw in (
        dict(donate=True, steps_per_dispatch=1),
        dict(donate=False, steps_per_dispatch=4),
        dict(donate=True, steps_per_dispatch=4),
        dict(donate=False, steps_per_dispatch=1, **paged),
        dict(donate=True, steps_per_dispatch=1, **paged),
        dict(donate=False, steps_per_dispatch=4, **paged),
        dict(donate=True, steps_per_dispatch=4, **paged),
    ):
        got, eng = run(**kw)
        assert got == base, kw
        if kw["steps_per_dispatch"] == 4:
            # K tokens per host sync: strictly fewer dispatches than steps
            assert eng.dispatches * 4 == eng.decode_steps
            assert eng.dispatches < sum(sp.max_new_tokens for sp in sps)


def test_k4_windowed_and_mla_archs_match_k1():
    """The fused scan through the modular window table (pre-mapped
    lookahead pages) and the MLA latent path reproduce K=1 exactly."""
    for arch, max_len, gen, pages, ps in (
        ("recurrentgemma-9b", 40, 20, 32, 4),  # decodes past the window
        ("deepseek-v2-lite-16b", 24, 6, 24, 4),
    ):
        cfg, model, comp = _compressed(arch)
        prompts = [_rand_prompt(9, 5, cfg.vocab), _rand_prompt(10, 11, cfg.vocab)]
        sps = [SamplingParams(max_new_tokens=gen)] * 2
        base = _stream(
            DecodeEngine(model, comp, max_batch=2, max_len=max_len, donate=False),
            prompts, sps,
        )
        got = _stream(
            DecodeEngine(
                model, comp, max_batch=2, max_len=max_len,
                steps_per_dispatch=4, num_pages=pages, page_size=ps,
            ),
            prompts, sps,
        )
        assert got == base, arch


# ---------------------------------------------------------------------------
# on-device stop detection: lanes freeze mid-scan
# ---------------------------------------------------------------------------


def test_lane_finishes_mid_scan_eos_and_budget():
    """With K=4, an EOS emitted at a non-boundary step index and a budget
    exhausted mid-scan must freeze those lanes on device: same streams and
    finish reasons as K=1, and sibling lanes unperturbed."""
    prompts = [_rand_prompt(200 + r, 4 + r) for r in range(3)]
    base_sps = [SamplingParams(max_new_tokens=9)] * 3
    base, _ = _stream(
        DecodeEngine(MODEL, COMP, max_batch=3, max_len=32, donate=False),
        prompts, base_sps,
    )
    # eos = lane 0's 2nd token -> fires at scan iteration 1 of dispatch 0;
    # lane 1's budget of 3 exhausts at iteration 2; lane 2 runs through
    eos = base[0][1]
    sps = [
        SamplingParams(max_new_tokens=9, eos_id=eos),
        SamplingParams(max_new_tokens=3),
        SamplingParams(max_new_tokens=9),
    ]
    want_tokens = [base[0][: base[0].index(eos)], base[1][:3], base[2]]
    want_reasons = ["eos", "length", "length"]
    for kw in (dict(), dict(num_pages=24, page_size=4)):
        toks, reasons = _stream(
            DecodeEngine(
                MODEL, COMP, max_batch=3, max_len=32, steps_per_dispatch=4, **kw
            ),
            prompts, sps,
        )
        assert toks == want_tokens, kw
        assert reasons == want_reasons, kw


def test_cache_full_freezes_at_capacity_k4():
    """A lane hitting the logical capacity mid-scan stops writing (its page
    table has no slot past max_len) and finishes cache_full, same as K=1."""
    prompt = _rand_prompt(7, 6)
    sps = [SamplingParams(max_new_tokens=50)]
    base = _stream(
        DecodeEngine(MODEL, COMP, max_batch=1, max_len=10, donate=False),
        [prompt], sps,
    )
    got = _stream(
        DecodeEngine(
            MODEL, COMP, max_batch=1, max_len=10, steps_per_dispatch=4,
            num_pages=8, page_size=2,
        ),
        [prompt], sps,
    )
    assert got == base
    assert base[1] == ["cache_full"] and len(base[0][0]) == 4


# ---------------------------------------------------------------------------
# preemption at dispatch boundaries
# ---------------------------------------------------------------------------


def test_preemption_at_dispatch_boundary_resumes_exactly():
    """K=4 + an undersized pool: ``ensure_steps`` reserves the whole
    dispatch, so preemption happens only between dispatches and the
    resumed request reproduces the un-preempted greedy stream."""
    prompts = [_rand_prompt(100 + r, 5) for r in range(2)]
    sps = [SamplingParams(max_new_tokens=8)] * 2
    ref = DecodeEngine(MODEL, COMP, max_batch=2, max_len=16, seed=0, donate=False)
    t_ref, r_ref = _stream(ref, prompts, sps)

    eng = DecodeEngine(
        MODEL, COMP, max_batch=2, max_len=16, seed=0,
        num_pages=8, page_size=2, steps_per_dispatch=4,
    )
    t, r = _stream(eng, prompts, sps)
    assert eng.preemptions > 0
    assert t == t_ref and r == r_ref
    assert all(x == "length" for x in r)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_monolithic_and_interleaves():
    """A long prompt absorbed in 8-token chunks (slab and paged, K=1 and
    K=4) reproduces the monolithic-prefill greedy streams; the short
    request decodes while the long prompt is still chunking."""
    prompts = [_rand_prompt(1, 21), _rand_prompt(2, 4)]
    sps = [SamplingParams(max_new_tokens=5), SamplingParams(max_new_tokens=8)]
    base = _stream(
        DecodeEngine(MODEL, COMP, max_batch=2, max_len=40, seed=3, donate=False),
        prompts, sps,
    )
    for kw in (
        dict(),
        dict(num_pages=24, page_size=4, steps_per_dispatch=4),
    ):
        eng = DecodeEngine(
            MODEL, COMP, max_batch=2, max_len=40, seed=3, prefill_chunk=8, **kw
        )
        got = _stream(eng, prompts, sps)
        assert got == base, kw
        assert eng.prefill_chunks == 3  # ceil(21 / 8)
        # the short prompt never waited for the long one's prefill
        assert eng.stats()["prefill_batches"] == 1


def test_batched_chunked_prefill_one_dispatch_per_step():
    """Two long prompts admitted together chunk in *one* dispatch per
    scheduling step (3 dispatches for 3+3 lane-chunks, not 6), with
    streams identical to the monolithic-prefill baseline."""
    prompts = [_rand_prompt(1, 21), _rand_prompt(4, 17)]
    sps = [SamplingParams(max_new_tokens=5), SamplingParams(max_new_tokens=6)]
    base = _stream(
        DecodeEngine(MODEL, COMP, max_batch=2, max_len=40, seed=3, donate=False),
        prompts, sps,
    )
    for kw in (dict(), dict(num_pages=24, page_size=4)):
        eng = DecodeEngine(
            MODEL, COMP, max_batch=2, max_len=40, seed=3, prefill_chunk=8, **kw
        )
        got = _stream(eng, prompts, sps)
        assert got == base, kw
        # ceil(21/8) == ceil(17/8) == 3 chunks per lane, absorbed together
        assert eng.prefill_chunks == 3


def test_chunked_prefill_mla_paged():
    cfg, model, comp = _compressed("deepseek-v2-lite-16b")
    prompts = [_rand_prompt(7, 17, cfg.vocab)]
    sps = [SamplingParams(max_new_tokens=4)]
    base = _stream(
        DecodeEngine(model, comp, max_batch=1, max_len=28, donate=False),
        prompts, sps,
    )
    eng = DecodeEngine(
        model, comp, max_batch=1, max_len=28, prefill_chunk=6,
        num_pages=16, page_size=4,
    )
    assert _stream(eng, prompts, sps) == base
    assert eng.prefill_chunks == 3


def test_chunked_prefill_gated_off_recurrent_and_windowed():
    """Recurrent-state archs silently keep monolithic prefill (their
    mixers cannot resume mid-prompt from the cache).  Sliding-window
    attention now chunks on the paged layout — its ring views reconstruct
    the live window — but stays gated on the slab; both sides are locked
    by tests/test_device_scheduler.py."""
    cfg, model, comp = _compressed("recurrentgemma-9b")
    eng = DecodeEngine(model, comp, max_batch=1, max_len=40, prefill_chunk=4)
    assert eng.prefill_chunk is None
    prompts = [_rand_prompt(3, 11, cfg.vocab)]
    sps = [SamplingParams(max_new_tokens=3)]
    base = _stream(
        DecodeEngine(model, comp, max_batch=1, max_len=40, donate=False),
        prompts, sps,
    )
    assert _stream(eng, prompts, sps) == base
    assert eng.prefill_chunks == 0


# ---------------------------------------------------------------------------
# incremental page-table sync + ensure_steps accounting
# ---------------------------------------------------------------------------


def test_device_tables_sync_incrementally():
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(model, max_batch=4, max_len=16, num_pages=16, page_size=2)
    t0 = pool.device_tables()
    assert pool.table_full_uploads == 1
    # no mutation: same arrays, no new sync
    assert pool.device_tables() is t0
    assert pool.table_syncs == 1
    # one lane mutates: exactly one dirty row scatters, others untouched
    assert pool.alloc_prefill(2, 5)
    t1 = pool.device_tables()
    assert pool.table_full_uploads == 1 and pool.table_row_syncs == 1
    np.testing.assert_array_equal(np.asarray(t1["full"]), pool._pt_full)
    assert pool.alloc_prefill(0, 3)
    pool.release(2)
    t2 = pool.device_tables()
    assert pool.table_row_syncs == 3  # lanes 0 and 2
    np.testing.assert_array_equal(np.asarray(t2["full"]), pool._pt_full)


def test_engine_run_uploads_tables_once_then_rows():
    eng = DecodeEngine(
        MODEL, COMP, max_batch=2, max_len=32, num_pages=16, page_size=8
    )
    prompts = [_rand_prompt(100 + r, 3 + 3 * r) for r in range(3)]
    sps = [SamplingParams(max_new_tokens=6)] * 3
    _stream(eng, prompts, sps)
    st = eng.stats()
    assert st["table_full_uploads"] == 1
    assert st["table_row_syncs"] > 0
    # incremental: far fewer rows moved than a per-dispatch full re-upload
    assert st["table_row_syncs"] < st["dispatches"] * eng.max_batch


def test_ensure_steps_reserves_all_k_writes():
    _, model, _ = _compressed("gpt2-paper")
    pool = PagedKVPool(
        model, max_batch=2, max_len=32, num_pages=8, page_size=2, lookahead=4
    )
    assert pool.alloc_prefill(0, 3)  # pages 0..1 + boundary page 2... -> 2 pages
    used = pool.used_pages
    # next 4 writes at pos 3..6 span pages 1..3: pages 2 and 3 are new
    assert pool.ensure_steps(0, 3, 4)
    assert pool.used_pages >= used + 1
    # all-or-nothing: an unsatisfiable reservation allocates nothing
    pool2 = PagedKVPool(
        model, max_batch=2, max_len=32, num_pages=3, page_size=2, lookahead=8
    )
    assert pool2.alloc_prefill(0, 4)  # 2 prompt pages + boundary page = 3
    free_before = pool2.free_pages
    assert not pool2.ensure_steps(0, 4, 8)  # needs 4 more pages, has 0
    assert pool2.free_pages == free_before


def test_donated_engine_reuses_pool_after_run():
    """After a donated run the engine's cache/table handles stay live: a
    second wave of requests on the same engine must serve correctly (the
    adopt_tables re-anchoring)."""
    eng = DecodeEngine(
        MODEL, COMP, max_batch=2, max_len=32, seed=3, num_pages=16, page_size=8
    )
    prompts = [_rand_prompt(100 + r, 3 + 3 * r) for r in range(2)]
    sps = [SamplingParams(max_new_tokens=4)] * 2
    first = _stream(eng, prompts, sps)
    again = _stream(eng, prompts, sps)  # slots + pages were fully recycled
    ref = _stream(
        DecodeEngine(
            MODEL, COMP, max_batch=2, max_len=32, seed=3, num_pages=16,
            page_size=8, donate=False,
        ),
        prompts, sps,
    )
    assert first[0] == ref[0]
    assert [len(t) for t in again[0]] == [len(t) for t in first[0]]
