"""Per-shard kernel route (``kernels.sharded``): the shard_map fast path.

Coverage, per the PR-6 acceptance matrix:

1. **Table remap unit** — :func:`shard_local_tables` is pure: global table
   in, per-shard table + residency mask out, with global sentinels and
   other shards' pages collapsing to the *local* sentinel; lanes with zero
   resident pages on a shard yield all-sentinel rows.
2. **Flash-stat combine** — :func:`combine_stats` over a named mesh axis
   reproduces the global softmax from per-chunk ``(acc, m, l)`` triples,
   dead chunks included.
3. **Kernel parity** (emulated 8-device mesh): ``paged_attn_shard_map``
   vs the single-shard Pallas-interpret oracle and vs the XLA gathered
   path — GQA, MLA-absorbed (``v_is_k`` + ``q2/k2``), windowed/modular
   tables, ragged lanes whose live pages land on different shards;
   ``nm_spmm_shard_map`` vs the reference.
4. **Routing** — ``shards > 1`` + active ``mesh_context`` + a non-XLA pick
   resolves to ``"shard_map"``; no context (or a failing divisibility
   guard, or a forced ``"shard_map"`` on an unsharded call) falls back.
5. **Engine streams** — on a (2, 4) mesh, greedy token streams through the
   forced shard_map route are bit-identical to the sharded-XLA route and
   to the single-device engine, for {slab, paged} × {dense, compressed}.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.configs import get_config
from repro.distributed.sharding import MODEL_AXIS
from repro.kernels import dispatch, ref
from repro.kernels.paged_attn import paged_attn_pallas, paged_attn_xla
from repro.kernels.sharded import (
    combine_stats,
    nm_spmm_shard_map,
    paged_attn_shard_map,
    shard_local_tables,
)
from repro.launch.mesh import make_local_mesh
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params

jax.config.update("jax_platform_name", "cpu")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


# ---------------------------------------------------------------------------
# shard-local table remap: pure unit
# ---------------------------------------------------------------------------


def test_shard_local_tables_remaps_and_masks():
    # global pool P=16, 4 shards x 4 pages; sentinel = 16
    tables = jnp.asarray(
        [[0, 7, 13, 16], [4, 5, 6, 7]], jnp.int32
    )
    local, res = shard_local_tables(tables, jnp.int32(1), 4)  # shard 1: 4..7
    np.testing.assert_array_equal(
        np.asarray(local), [[4, 3, 4, 4], [0, 1, 2, 3]]
    )
    np.testing.assert_array_equal(
        np.asarray(res), [[False, True, False, False], [True] * 4]
    )
    assert local.dtype == tables.dtype


def test_shard_local_tables_zero_resident_lane():
    # lane 0's only page lives on shard 3; shards 0-2 see all-sentinel rows
    tables = jnp.asarray([[13, 16, 16]], jnp.int32)
    for shard in range(3):
        local, res = shard_local_tables(tables, jnp.int32(shard), 4)
        np.testing.assert_array_equal(np.asarray(local), [[4, 4, 4]])
        assert not np.asarray(res).any()
    local, res = shard_local_tables(tables, jnp.int32(3), 4)
    np.testing.assert_array_equal(np.asarray(local), [[1, 4, 4]])
    np.testing.assert_array_equal(np.asarray(res), [[True, False, False]])


def test_shard_local_tables_global_sentinel_never_resident():
    # the global sentinel (= global pool size) maps to the local sentinel
    # on every shard, including the last one
    tables = jnp.full((1, 2), 16, jnp.int32)
    for shard in range(4):
        local, res = shard_local_tables(tables, jnp.int32(shard), 4)
        assert (np.asarray(local) == 4).all() and not np.asarray(res).any()


# ---------------------------------------------------------------------------
# flash-stat combine over a named axis
# ---------------------------------------------------------------------------


@needs8
def test_combine_stats_matches_global_softmax():
    mesh = make_local_mesh(4, data=2)
    rng = np.random.default_rng(0)
    g, s, dv, shards = 3, 16, 5, 4
    scores = jnp.asarray(rng.normal(size=(g, s)) * 3, jnp.float32)
    vals = jnp.asarray(rng.normal(size=(s, dv)), jnp.float32)
    # dead chunk: mask the last quarter of every row (m=-1e30, l=0, acc=0)
    scores = scores.at[:, -(s // shards):].set(-1e30)
    accs, ms, ls = [], [], []
    for c in range(shards):
        sc = scores[:, c * (s // shards):(c + 1) * (s // shards)]
        vc = vals[c * (s // shards):(c + 1) * (s // shards)]
        m = jnp.max(sc, axis=-1)
        pexp = jnp.where(sc > -1e29, jnp.exp(sc - m[:, None]), 0.0)
        ms.append(m)
        ls.append(jnp.sum(pexp, axis=-1))
        accs.append(pexp @ vc)
    acc, m, l = jnp.stack(accs), jnp.stack(ms), jnp.stack(ls)

    def body(a, mm, ll):
        return combine_stats(a[0], mm[0], ll[0], MODEL_AXIS)[None]

    out = shard_map(
        body, mesh,
        in_specs=(P(MODEL_AXIS), P(MODEL_AXIS), P(MODEL_AXIS)),
        out_specs=P(MODEL_AXIS), check_rep=False,
    )(acc, m, l)
    live = jnp.where(scores > -1e29, scores, -jnp.inf)
    want = jax.nn.softmax(live, axis=-1) @ vals
    for c in range(shards):  # every shard holds the same combined result
        np.testing.assert_allclose(
            np.asarray(out[c]), np.asarray(want), atol=1e-5
        )


# ---------------------------------------------------------------------------
# paged-attention parity on the emulated mesh
# ---------------------------------------------------------------------------


def _gqa_case(seed=0, hkv=2, g=3, d=8, dv=8, pool=16, ps=4, n_slots=5):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(3, hkv, g, d)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(pool, ps, hkv, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(pool, ps, hkv, dv)), jnp.float32)
    # ragged lanes; live pages deliberately land on different shards
    # (4 shards x 4 pages: ids 0/7/13 hit shards 0, 1, 3), lane 2 has a
    # single page (zero resident pages on three shards), sentinel = 16
    tables = np.full((3, n_slots), pool, np.int32)
    tables[0, :3] = [0, 7, 13]
    tables[1, :5] = [2, 5, 9, 11, 15]
    tables[2, :1] = [4]
    tables = jnp.asarray(tables)
    lengths = jnp.asarray([11, 18, 2], jnp.int32)
    return q, k_pages, v_pages, tables, lengths


@needs8
def test_paged_attn_shard_map_gqa_parity():
    mesh = make_local_mesh(4, data=2)
    q, k_pages, v_pages, tables, lengths = _gqa_case()
    kw = dict(scale=0.3)
    want = paged_attn_xla(q, k_pages, v_pages, tables, lengths, **kw)
    oracle = paged_attn_pallas(
        q, k_pages, v_pages, tables, lengths, interpret=True, **kw
    )
    got = paged_attn_shard_map(
        q, k_pages, v_pages, tables, lengths, mesh=mesh, **kw
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), atol=2e-5)
    # the per-shard inner kernel honors the forced interpret route (the
    # Pallas body runs under the wrapper, not the gathered stats path)
    with dispatch.force_mode("interpret"):
        got_i = paged_attn_shard_map(
            q, k_pages, v_pages, tables, lengths, mesh=mesh, **kw
        )
    np.testing.assert_allclose(
        np.asarray(got_i), np.asarray(oracle), atol=2e-5
    )


@needs8
def test_paged_attn_shard_map_windowed_modular():
    mesh = make_local_mesh(4, data=2)
    rng = np.random.default_rng(1)
    hkv, g, d, pool, ps, win_slots = 1, 2, 8, 16, 4, 3
    q = jnp.asarray(rng.normal(size=(2, hkv, g, d)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(pool, ps, hkv, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(pool, ps, hkv, d)), jnp.float32)
    # modular tables: slot s holds logical page ≡ s (mod 3); physical ids
    # spread across shards, unreached slots sentinel
    tables = jnp.asarray([[1, 6, 12], [3, 16, 16]], jnp.int32)
    lengths = jnp.asarray([10, 3], jnp.int32)
    kw = dict(scale=0.25, window=8, win_slots=win_slots)
    want = paged_attn_xla(q, k_pages, v_pages, tables, lengths, **kw)
    oracle = paged_attn_pallas(
        q, k_pages, v_pages, tables, lengths, interpret=True, **kw
    )
    got = paged_attn_shard_map(
        q, k_pages, v_pages, tables, lengths, mesh=mesh, **kw
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), atol=2e-5)


@needs8
def test_paged_attn_shard_map_mla_absorbed():
    """MLA decode shape: Hkv=1, G=H, v_is_k (latent pool streamed once),
    q2/k2 carry the RoPE scores."""
    mesh = make_local_mesh(4, data=2)
    rng = np.random.default_rng(2)
    h, lat, rd, pool, ps = 4, 16, 8, 8, 2
    q = jnp.asarray(rng.normal(size=(2, 1, h, lat)), jnp.float32)
    q2 = jnp.asarray(rng.normal(size=(2, 1, h, rd)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(pool, ps, 1, lat)), jnp.float32)
    krope = jnp.asarray(rng.normal(size=(pool, ps, 1, rd)), jnp.float32)
    tables = jnp.asarray([[0, 3, 5, 8], [6, 8, 8, 8]], jnp.int32)
    lengths = jnp.asarray([6, 1], jnp.int32)
    kw = dict(scale=0.2, q2=q2, k2_pages=krope, v_is_k=True)
    want = paged_attn_xla(q, ckv, None, tables, lengths, **kw)
    oracle = paged_attn_pallas(
        q, ckv, None, tables, lengths, interpret=True, **kw
    )
    got = paged_attn_shard_map(q, ckv, None, tables, lengths, mesh=mesh, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), atol=2e-5)


@needs8
def test_nm_spmm_shard_map_parity():
    mesh = make_local_mesh(4, data=2)
    rng = np.random.default_rng(3)
    k, o = 64, 48
    x = jnp.asarray(rng.normal(size=(5, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, o)), jnp.float32)
    v, i = ref.nm_compress(w, 2, 4, 0)
    want = ref.nm_spmm_ref(x, v, i, 2, 4)
    got = nm_spmm_shard_map(x, v, i, 2, 4, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4
    )
    with dispatch.force_mode("interpret"):  # Pallas body per shard
        got_i = nm_spmm_shard_map(x, v, i, 2, 4, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got_i), np.asarray(want), atol=1e-4, rtol=1e-4
    )


# ---------------------------------------------------------------------------
# routing: when does a shards>1 call take the wrapper?
# ---------------------------------------------------------------------------


@needs8
def test_shard_route_resolution():
    mesh = make_local_mesh(4, data=2)
    info = dict(b=2, n_slots=4, page_size=4, num_pages=16, shards=4)
    # no mesh context: XLA backstop, exactly the pre-PR-6 behavior
    assert dispatch.resolve("paged_attn", **info)[0] == "xla"
    with dispatch.mesh_context(mesh):
        # CPU default pick is "xla" — GSPMD keeps the gathered path
        assert dispatch.resolve("paged_attn", **info)[0] == "xla"
        # any non-xla pick (forced, env, or the TPU pallas default)
        # upgrades to the wrapper instead of being forced off the kernel
        with dispatch.force_mode("interpret"):
            assert dispatch.resolve("paged_attn", **info)[0] == "shard_map"
        with dispatch.force_mode("shard_map"):
            assert dispatch.resolve("paged_attn", **info)[0] == "shard_map"
            # ... but never when the divisibility guard refuses
            bad = dict(info, num_pages=18)
            assert dispatch.resolve("paged_attn", **bad)[0] == "xla"
            # legacy call sites without num_pages keep the backstop
            legacy = dict(b=2, n_slots=4, page_size=4, shards=4)
            assert dispatch.resolve("paged_attn", **legacy)[0] == "xla"
            # forced shard_map on an unsharded call: backend default
            flat = dict(info, shards=1)
            assert dispatch.resolve("paged_attn", **flat)[0] == "xla"
        # nm_spmm: whole groups per shard or no wrapper
        nm = dict(b=4, k=64, o=48, n=2, m=4, shards=4)
        with dispatch.force_mode("shard_map"):
            assert dispatch.resolve("nm_spmm", **nm)[0] == "shard_map"
            odd = dict(nm, k=72)  # 72 % (4·4) != 0
            assert dispatch.resolve("nm_spmm", **odd)[0] == "xla"
    with dispatch.force_mode("shard_map"):  # context gone again
        assert dispatch.resolve("paged_attn", **info)[0] == "xla"


# ---------------------------------------------------------------------------
# engine streams: shard_map route == sharded XLA route == single device
# ---------------------------------------------------------------------------


def _trees(arch="gpt2-paper"):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(2, 4))
    )
    sparse = recipe.export_sparse(params)
    return cfg, model, sparse, compress_params(sparse, recipe.sparsity)


def _prompts(cfg, lens, seed=100):
    return [
        [
            int(t)
            for t in jax.random.randint(
                jax.random.PRNGKey(seed + i), (n,), 0, cfg.vocab
            )
        ]
        for i, n in enumerate(lens)
    ]


def _stream(eng, prompts, sps):
    uids = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    res = eng.run()
    return [res[u].tokens for u in uids]


@needs8
def test_annotate_reduction_tp_stamps_and_keeps_tree_alignment():
    from repro.distributed.compressed_pspecs import (
        annotate_reduction_tp,
        serving_param_shardings,
    )
    from repro.sparse_infer.compress import CompressedTensor

    cfg, model, sparse, comp = _trees()
    mesh = make_local_mesh(4, data=2)
    ann = annotate_reduction_tp(comp, mesh, cfg=cfg)
    cts = [
        x for x in jax.tree_util.tree_leaves(
            ann, is_leaf=lambda x: isinstance(x, CompressedTensor)
        )
        if isinstance(x, CompressedTensor)
    ]
    assert cts and any(ct.rshards == 4 for ct in cts)
    # the spec tree copies rshards into the aux, so device_put / jit
    # in_shardings see matching treedefs (the bug this ordering prevents)
    sh = serving_param_shardings(mesh, ann, cfg=cfg)
    assert jax.tree_util.tree_structure(ann) == jax.tree_util.tree_structure(sh)
    jax.block_until_ready(jax.device_put(ann, sh))


@needs8
@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("paged", [False, True])
def test_engine_streams_identical_across_kernel_routes(paged, compressed):
    """Single-device == mesh(2,4)+XLA route == mesh(2,4)+shard_map route."""
    cfg, model, sparse, comp = _trees()
    tree = comp if compressed else sparse
    mesh = make_local_mesh(4, data=2)
    prompts = _prompts(cfg, [7, 4, 9])
    sps = [SamplingParams(max_new_tokens=8)] * 3
    kw = dict(max_batch=3, max_len=24, seed=3)
    paged_kw = dict(num_pages=24, page_size=4) if paged else {}
    base = _stream(
        DecodeEngine(model, tree, donate=False, **kw, **paged_kw),
        prompts, sps,
    )
    eng_xla = DecodeEngine(model, tree, mesh=mesh, **kw, **paged_kw)
    assert eng_xla.kernel_route() == ("xla" if paged else "slab")
    got_xla = _stream(eng_xla, prompts, sps)
    # the forced route resolves at trace time: keep the force active for
    # the whole run (prefill + decode executables trace inside it)
    with dispatch.force_mode("shard_map"):
        eng_sm = DecodeEngine(model, tree, mesh=mesh, **kw, **paged_kw)
        assert eng_sm.kernel_route() == ("shard_map" if paged else "slab")
        got_sm = _stream(eng_sm, prompts, sps)
    assert got_xla == base
    assert got_sm == base
