"""RecurrentGemma-9B [arXiv:2402.19427; unverified]: Griffin hybrid.

38L with pattern (RG-LRU, RG-LRU, local-attention) — 12 full periods + 2
trailing recurrent layers; d_model 4096, 16 heads MQA (kv=1, head_dim 256),
d_ff 12288, vocab 256000, local attention window 2048, lru_width 4096; GeLU
MLP, RMSNorm, tied embeddings. Sub-quadratic (bounded KV + recurrent state)
=> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig
from repro.models.recurrent import RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    mlp="gelu",
    norm="rms",
    rope="rope",
    rope_theta=1e4,
    local_window=2048,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    layer_pattern=("rec", "rec", "attn"),
    sub_quadratic=True,
    source="arXiv:2402.19427; unverified",
)
