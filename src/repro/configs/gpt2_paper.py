"""The paper's own model family: a GPT-2-style decoder (Radford et al. 2019).

The paper fine-tunes GPT-2 (124M) on WikiText-2/-103 with 2:4 sparsity on
all Conv1D modules (= our attention/MLP matmuls). This config is the
end-to-end driver's ~100M-class model and the reproduction benchmarks'
backbone. GPT-2: 12L, d_model 768, 12 MHA heads, d_ff 3072, GeLU, LayerNorm,
learned positions (we use RoPE; recorded deviation), vocab 50257 → padded to
50304 for M-divisibility.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-paper",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_ff=3072,
    vocab=50304,
    head_dim=64,
    qkv_bias=True,
    o_bias=True,
    mlp="gelu",
    norm="ln",
    rope="rope",
    tie_embeddings=True,
    source="Radford et al. 2019 (paper §6 task 4)",
)
