"""StarCoder2-3B [arXiv:2402.19173; hf]: dense GQA code LM.

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152; GQA + RoPE,
GeLU MLP with biases, LayerNorm (per the StarCoder2 paper's config).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv=2,
    d_ff=12288,
    vocab=49152,
    head_dim=128,
    qkv_bias=True,
    o_bias=True,
    mlp="gelu",
    norm="ln",
    rope="rope",
    rope_theta=1e5,
    source="arXiv:2402.19173; hf",
)
