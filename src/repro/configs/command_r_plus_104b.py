"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus; unverified].

64L, d_model 12288, 96 heads (GQA kv=8), d_ff 33792, vocab 256000; SwiGLU,
LayerNorm, RoPE, no biases, tied embeddings (Cohere convention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    mlp="swiglu",
    norm="ln",
    rope="rope",
    rope_theta=75e4,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-plus; unverified",
)
