"""Mamba2-2.7B [arXiv:2405.21060; unverified]: attention-free SSD LM.

64L, d_model 2560, ssm_state 128, head_dim 64 (=> 80 heads at expand 2),
vocab 50280; no attention, no MLP (the Mamba-2 mixer is the whole block);
tied embeddings. Sub-quadratic => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # unused (attention-free)
    n_kv=1,
    d_ff=0,
    vocab=50280,
    mlp="swiglu",  # unused
    norm="rms",
    rope="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, conv_width=4, chunk=256),
    sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
)
