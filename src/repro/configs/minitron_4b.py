"""Minitron-4B [arXiv:2407.14679; hf]: pruned-Nemotron dense LM.

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 9216, vocab 256000.
Nemotron uses squared-ReLU MLPs; the framework's closest activation is GeLU
(recorded deviation — activation choice is orthogonal to STEP).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
    mlp="gelu",
    norm="ln",
    rope="rope",
    rope_theta=1e4,
    source="arXiv:2407.14679; hf",
)
