"""MusicGen-large backbone [arXiv:2306.05284; hf]: decoder-only over EnCodec
tokens.

48L, d_model 2048, 32 heads (MHA — kv=32), d_ff 8192, vocab 2048 (EnCodec
codebook). The EnCodec frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (audio_stub, 512-d) per the assignment; labels
remain codebook token ids. MusicGen uses sinusoidal positions + GeLU + LN;
we keep GeLU/LN and substitute RoPE for sinusoidal positions (recorded
deviation — positional encoding choice is orthogonal to STEP).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    mlp="gelu",
    norm="ln",
    rope="rope",
    frontend="audio_stub",
    source="arXiv:2306.05284; hf",
)
