"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf]: VLM with M-RoPE.

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936; SwiGLU,
RMSNorm, QKV bias, M-RoPE (temporal/height/width position streams), tied
embeddings. The ViT frontend is a STUB: ``input_specs()`` feeds precomputed
patch embeddings (vision_stub, 1176-d = 14x14 patch x 2 frames x 3 ch) with
3-D positions; dynamic resolution enters only through the position streams.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    mlp="swiglu",
    norm="rms",
    rope="mrope",
    rope_theta=1e6,
    tie_embeddings=True,
    frontend="vision_stub",
    source="arXiv:2409.12191; hf",
)
