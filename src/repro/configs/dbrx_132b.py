"""DBRX 132B [hf:databricks/dbrx-base; unverified]: fine-grained MoE.

40L, d_model 6144, 48 heads (GQA kv=8), 16 experts top-4 with expert d_ff
10752, vocab 100352; SwiGLU experts, RoPE (theta 5e5), LayerNorm.
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=10752,
    vocab=100352,
    head_dim=128,
    mlp="swiglu",
    norm="ln",
    rope="rope",
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752, n_shared=0),
    source="hf:databricks/dbrx-base; unverified",
)
