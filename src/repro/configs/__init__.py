"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

One module per assigned architecture (exact public-literature config), plus
the paper's own GPT-2-style model for the reproduction benchmarks.
``get_config(name, smoke=True)`` returns the reduced same-family variant.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, reduced

from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.qwen15_110b import CONFIG as _qwen15
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.mamba2_27b import CONFIG as _mamba2
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.gpt2_paper import CONFIG as _gpt2

_REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _starcoder2,
        _qwen15,
        _minitron,
        _command_r,
        _deepseek,
        _dbrx,
        _mamba2,
        _musicgen,
        _qwen2vl,
        _rgemma,
        _gpt2,
    )
}

ASSIGNED_ARCHS = tuple(n for n in _REGISTRY if n != "gpt2-paper")


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    cfg = _REGISTRY[name]
    return reduced(cfg) if smoke else cfg
