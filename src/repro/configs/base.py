"""Architecture & shape configuration dataclasses.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :data:`SHAPES`. ``reduced()`` produces the
small-family smoke variant (same code paths, tiny dims) exercised by the
CPU tests; the full config is only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.recurrent import RGLRUConfig
from repro.models.mla import MLAConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    o_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu
    norm: str = "rms"  # rms | ln
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10000.0
    local_window: Optional[int] = None  # sliding-window attention
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # hybrid layer pattern, e.g. ("rec", "rec", "attn"); None = all-attn
    # (or all-ssm when family == "ssm")
    layer_pattern: Optional[Sequence[str]] = None
    frontend: str = "none"  # none | audio_stub | vision_stub
    sub_quadratic: bool = False  # eligible for the long_500k cell
    param_dtype: str = "bfloat16"
    source: str = ""  # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def block_kinds(self) -> list[str]:
        """Per-layer block kinds, length n_layers."""
        if self.layer_pattern is None:
            base = "ssm" if self.family == "ssm" else "attn"
            return [base] * self.n_layers
        pat = list(self.layer_pattern)
        out = [pat[i % len(pat)] for i in range(self.n_layers)]
        return out

    def shapes(self) -> list[ShapeSpec]:
        """The assigned shape cells for this arch (long_500k gated on
        sub-quadratic support — see DESIGN.md §4)."""
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.sub_quadratic:
            out.append(SHAPES["long_500k"])
        return out


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family variant for CPU smoke tests."""
    n_kv = min(cfg.n_kv, 2)
    n_heads = max(4, n_kv * 2)
    base = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.layer_pattern is None else 2 * len(cfg.layer_pattern)),
        d_model=64,
        n_heads=n_heads,
        n_kv=n_kv,
        d_ff=128,
        vocab=256,
        head_dim=16,
    )
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
            n_shared=min(cfg.moe.n_shared, 1),
            capacity_factor=8.0,  # no token dropping in the tiny smoke models
        )
    if cfg.mla is not None:
        base["mla"] = MLAConfig(kv_lora=32, rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
        base["head_dim"] = None
    if cfg.ssm is not None:
        base["ssm"] = SSMConfig(d_state=16, head_dim=8, expand=2,
                                n_groups=1, conv_width=4, chunk=8)
    if cfg.rglru is not None:
        base["rglru"] = RGLRUConfig(lru_width=64, conv_width=4)
    if cfg.local_window is not None:
        base["local_window"] = 16
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
