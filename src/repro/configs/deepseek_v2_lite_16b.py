"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf]: MLA + fine-grained MoE.

27L, d_model 2048; MLA (kv_lora 512, rope head 64, nope head 128, 16 heads);
MoE: 64 routed experts top-6 + 2 shared, expert d_ff 1408; first layer uses a
dense 10944-wide MLP; vocab 102400.

Assignment-sheet note: the line says both "MoE 64e top-6" and "2 shared +
160 routed"; the published V2-*Lite* is 64 routed + 2 shared (160 is full
V2). We follow the primary "64e top-6" spec (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=10944,  # the dense first layer's MLP width
    vocab=102400,
    mlp="swiglu",
    norm="rms",
    rope="rope",
    rope_theta=1e4,
    mla=MLAConfig(kv_lora=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        first_layer_dense=True,
    ),
    source="arXiv:2405.04434; hf",
)
