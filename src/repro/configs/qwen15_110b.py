"""Qwen1.5-110B [hf:Qwen/Qwen1.5-*; hf]: dense GQA LM with QKV bias.

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 49152, vocab 152064; SwiGLU,
RMSNorm, RoPE (theta 1e6), QKV bias (the Qwen family signature).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=49152,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    mlp="swiglu",
    norm="rms",
    rope="rope",
    rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-110B; hf",
)
