"""Per-request token sampling, vectorized over the decode batch.

Each slot in the engine's batch carries its own ``(temperature, top_k)``;
this module samples the whole batch in one jittable call so heterogeneous
requests share a single decode step. ``temperature == 0`` means greedy
(argmax) and ``top_k == 0`` disables the top-k filter — both resolved with
``jnp.where`` so the function stays trace-stable across request mixes.

:func:`advance_stops` is the device half of the engine's stop handling:
inside a K-steps-per-dispatch fused decode the host cannot see mid-scan
tokens, so per-lane EOS / token-budget / capacity stops are detected on
device and finished lanes freeze (stop sampling, stop writing, stop
advancing ``cache["len"]``) until the host absorbs the token block at the
dispatch boundary and replays the same rules.

:func:`request_keys` derives the per-row sampling keys: the key for a
request's c-th generated token is ``fold_in(fold_in(base, uid), c)``,
a pure function of (request, token index).  Sampled streams are therefore
identical no matter which lane a request lands in, which dispatch
boundary splits its decode, which scheduler (fixed-K sync or the
device-resident run-until-stop loop) drives it, or whether it was
preempted and resumed — the property the scheduler-equivalence tests pin.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy."""

    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0  # 0 = no top-k filtering
    max_new_tokens: int = 32
    eos_id: int = -1  # -1 = never stop on a token


def request_keys(
    base_key: jax.Array,
    uids: jnp.ndarray,  # (B,) int32 request ids
    counts: jnp.ndarray,  # (B,) int32 generated-token index per row
) -> jax.Array:
    """Per-row sampling keys: ``fold_in(fold_in(base, uid), count)``.

    Deterministic per (request, generated-token index), so a request's
    sampled stream does not depend on its lane, its batch-mates, or how
    dispatches were cut — only on the engine's base seed.
    """
    return jax.vmap(
        lambda u, c: jax.random.fold_in(jax.random.fold_in(base_key, u), c)
    )(uids, counts)


def sample_tokens(
    logits: jnp.ndarray,  # (B, V)
    temperature: jnp.ndarray,  # (B,) f32; 0 = greedy
    top_k: jnp.ndarray,  # (B,) int32; 0 = disabled
    key: jax.Array,
    *,
    need_sample: bool = True,  # static: False = every row is greedy
    need_topk: bool = True,  # static: False = no row filters by top-k
    rowwise: bool = False,  # static: key is a (B,)-stacked per-row key array
) -> jnp.ndarray:
    """Sample one token per batch row under per-row (temperature, top_k).

    The ``need_*`` flags are static (the engine computes them host-side from
    the current request mix) so all-greedy batches — the common serving
    case — compile to a bare argmax with no O(B·V·logV) sort and no
    categorical draw in the decode hot path.

    With ``rowwise=True`` ``key`` is a stacked per-row key array (from
    :func:`request_keys`) and each row draws from its own key; otherwise
    one key is shared across the batch (legacy path, kept for tests).
    """
    lf = logits.astype(jnp.float32)
    v = lf.shape[-1]
    if need_topk:
        # per-row top-k cutoff: the k-th largest logit (row-sorted descending)
        sorted_desc = jnp.sort(lf, axis=-1)[:, ::-1]
        kidx = jnp.clip(top_k - 1, 0, v - 1)
        kth = jnp.take_along_axis(sorted_desc, kidx[:, None], axis=-1)  # (B, 1)
        cut = (top_k[:, None] > 0) & (lf < kth)
        lf = jnp.where(cut, -jnp.inf, lf)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if not need_sample:
        return greedy
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    scaled = lf / safe_t[:, None]
    if rowwise:
        sampled = jax.vmap(
            lambda kk, row: jax.random.categorical(kk, row)
        )(key, scaled)
    else:
        sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)


def filtered_probs(
    logits: jnp.ndarray,  # (..., V)
    temperature: jnp.ndarray,  # (...,) f32; 0 = greedy
    top_k: jnp.ndarray,  # (...,) int32; 0 = disabled
    *,
    need_topk: bool = True,  # static: False = no row filters by top-k
) -> jnp.ndarray:
    """Post-filter sampling distribution per row, in lockstep with
    :func:`sample_tokens`: the same top-k cutoff, the same temperature
    scaling, then a softmax — exactly the distribution the categorical in
    ``sample_tokens`` draws from.  ``temperature == 0`` rows return the
    one-hot argmax distribution, which makes the speculative
    rejection-sampling rule (:func:`spec_accept`) degenerate *exactly* to
    greedy longest-prefix acceptance: the accept probability
    ``min(1, p_v(d)/p_d(d))`` is 1 on an argmax match and 0 otherwise, and
    the residual ``max(p_v - p_d, 0)`` renormalizes to the verifier's
    one-hot argmax.
    """
    lf = logits.astype(jnp.float32)
    v = lf.shape[-1]
    if need_topk:
        sorted_desc = jnp.sort(lf, axis=-1)[..., ::-1]
        kidx = jnp.clip(top_k - 1, 0, v - 1)
        kth = jnp.take_along_axis(sorted_desc, kidx[..., None], axis=-1)
        cut = (top_k[..., None] > 0) & (lf < kth)
        lf = jnp.where(cut, -jnp.inf, lf)
    one_hot = jax.nn.one_hot(jnp.argmax(lf, axis=-1), v, dtype=jnp.float32)
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    probs = jax.nn.softmax(lf / safe_t[..., None], axis=-1)
    return jnp.where((temperature > 0)[..., None], probs, one_hot)


def spec_accept(
    drafts: jnp.ndarray,  # (B, G) int32 drafter proposals
    p_draft: jnp.ndarray,  # (B, G, V) drafter filtered probs (zero rows
    #     at slots >= gi; ignored when need_sample=False)
    p_verify: jnp.ndarray,  # (B, G+1, V) verifier filtered probs; slot j
    #     scores the token *after* input j, slot G the bonus position
    gi: jnp.ndarray,  # (B,) int32 drafts actually proposed per lane
    accept_key: jax.Array,  # (B,) stacked per-row keys (accept draws)
    resid_key: jax.Array,  # (B,) stacked per-row keys (residual draw)
    *,
    need_sample: bool = True,  # static: False = every row is greedy
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The speculative accept/reject rule, vectorized over the batch.

    Returns ``(tokens, n_acc)`` where row ``i`` of ``tokens`` (shape
    ``(B, G+1)``) holds the ``n_acc[i] + 1`` tokens the lane emits this
    round — the accepted draft prefix followed by one verifier token (the
    correction on a rejection, the bonus on full acceptance) — and slots
    past that are zero.

    Greedy (``need_sample=False``): longest prefix of drafts matching the
    verifier argmax; the trailing token is the verifier argmax at the
    first mismatch (or the bonus slot).  The emitted stream is therefore
    bit-identical to plain greedy decoding under the verifier.

    Sampled: draft ``j`` is accepted with probability
    ``min(1, p_v(d_j) / p_d(d_j))``; on the first rejection the trailing
    token draws from the residual ``normalize(max(p_v - p_d, 0))``, on
    full acceptance from ``p_v`` at the bonus slot (``p_draft`` is
    zero-padded there, so the residual *is* ``p_v``).  This is the
    standard speculative-sampling identity: the emitted distribution is
    exactly the verifier's, whatever the drafter proposed.  Rows with
    ``temperature == 0`` carry one-hot distributions (see
    :func:`filtered_probs`) and reduce to the greedy rule exactly.
    """
    b, g = drafts.shape
    slots = jnp.arange(g)[None, :]
    proposed = slots < gi[:, None]
    if not need_sample:
        v_top = jnp.argmax(p_verify, axis=-1).astype(jnp.int32)  # (B, G+1)
        acc = proposed & (drafts == v_top[:, :g])
        prefix = jnp.cumprod(acc.astype(jnp.int32), axis=1)
        n = prefix.sum(axis=1).astype(jnp.int32)
        fix = jnp.take_along_axis(v_top, n[:, None], axis=1)[:, 0]
    else:
        u = jax.vmap(lambda k: jax.random.uniform(k, (g,)))(accept_key)
        p_d_at = jnp.take_along_axis(p_draft, drafts[..., None], axis=-1)[..., 0]
        p_v_at = jnp.take_along_axis(
            p_verify[:, :g], drafts[..., None], axis=-1
        )[..., 0]
        ratio = p_v_at / jnp.maximum(p_d_at, 1e-20)
        acc = proposed & (u < jnp.minimum(ratio, 1.0))
        prefix = jnp.cumprod(acc.astype(jnp.int32), axis=1)
        n = prefix.sum(axis=1).astype(jnp.int32)
        # zero-pad the drafter at the bonus slot: n == gi (full accept)
        # then draws the trailing token from p_v itself
        p_d_pad = jnp.concatenate(
            [p_draft, jnp.zeros_like(p_draft[:, :1])], axis=1
        )
        p_v_n = jnp.take_along_axis(p_verify, n[:, None, None], axis=1)[:, 0]
        p_d_n = jnp.take_along_axis(p_d_pad, n[:, None, None], axis=1)[:, 0]
        resid = jnp.maximum(p_v_n - p_d_n, 0.0)
        rs = resid.sum(axis=-1, keepdims=True)
        # p_d == p_v makes the residual vanish — but then the accept
        # probability was 1, so the guard only shields numeric dust
        resid = jnp.where(rs > 1e-9, resid / rs, p_v_n)
        fix = jax.vmap(
            lambda k, p: jax.random.categorical(k, jnp.log(p))
        )(resid_key, resid).astype(jnp.int32)
    j = jnp.arange(g + 1)[None, :]
    drafts_pad = jnp.concatenate(
        [drafts, jnp.zeros((b, 1), drafts.dtype)], axis=1
    )
    tokens = jnp.where(
        j < n[:, None], drafts_pad,
        jnp.where(j == n[:, None], fix[:, None], 0),
    )
    return tokens.astype(jnp.int32), n


def advance_stops(
    tokens: jnp.ndarray,  # (B,) int32: freshly sampled, pre-masking
    active: jnp.ndarray,  # (B,) bool: lanes decoding this iteration
    budget: jnp.ndarray,  # (B,) int32: tokens each lane may still append
    eos_id: jnp.ndarray,  # (B,) int32: per-lane eos (< 0 = never)
    new_len: jnp.ndarray,  # (B,) int32: prompt+generated after this append
    max_len: int,  # engine-wide logical capacity
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Apply one decode iteration's stop rules on device.

    Returns ``(tokens, active, budget)`` where finished/idle lanes emit 0
    and drop out of ``active``.  Mirrors the host's ``_absorb`` exactly so
    a lane frozen mid-scan stops at the same token the host-side replay of
    the ``(K, B)`` block will stop at: EOS finishes without appending; an
    appended token finishes on an exhausted ``max_new_tokens`` budget or on
    hitting the logical cache capacity.
    """
    tokens = jnp.where(active, tokens, 0)
    eos_hit = active & (eos_id >= 0) & (tokens == eos_id)
    appended = active & ~eos_hit
    budget = budget - appended.astype(budget.dtype)
    done = eos_hit | (appended & ((budget <= 0) | (new_len >= max_len)))
    return tokens, active & ~done, budget
