"""Host-side paged KV-cache pool manager for the serving engine.

``PagedKVPool`` owns

- the device cache tree (one ``(num_pages, page_size, ...)`` pool array per
  attention/MLA layer, allocated via ``model.init_cache(layout=PagedLayout)``;
  SSM / RG-LRU states stay per-lane),
- the free-page list (page ids are *global*: one id reserves a
  ``page_size``-token block in **every** paged layer's pool at once), and
- the per-lane page tables, mirrored host-side in numpy and synced to the
  device (``cache["tables"]``) **incrementally**: mutations mark their lane
  dirty, and ``device_tables`` scatters only the dirty rows into the
  resident device arrays instead of re-uploading every lane's full table
  each step (the PR-3 engine re-built the whole ``tables`` dict per decode
  step).  The first call uploads everything once; steady-state decode with
  a K-step dispatch uploads ``O(dirty lanes)`` rows per *dispatch*, and
  zero when nothing changed.

Two tables exist, depending on what the architecture needs:

- ``full`` — append-only, ``ceil(max_len / page_size)`` slots per lane,
  used by non-windowed attention and MLA layers.  Slot ``p`` maps logical
  positions ``[p·ps, (p+1)·ps)``.
- ``win`` — modular, ``ceil((window + lookahead - 1) / page_size) + 1``
  slots per lane, used by sliding-window layers.  Position ``pos`` lives in
  slot ``(pos // ps) % n_slots``; when the window slides wholly past a page
  the page is evicted (returned to the free list) and its slot reused.  The
  ``lookahead`` widening guarantees the pages a K-step fused dispatch will
  write can all be pre-mapped *before* the dispatch without a modular slot
  collision against any page still live mid-scan.

The pool performs no scheduling itself: the engine asks ``can_admit`` /
``alloc_prefill`` at admission, ``ensure_steps(lane, pos, k)`` before every
decode dispatch (reserving *all* K writes so mid-scan exhaustion cannot
occur), and ``release`` on finish or preemption.  When the engine donates
the cache into its jitted executables it must hand the returned table
arrays back via ``adopt_tables`` — the device buffers the pool scattered
into were consumed by the donation.

**Shared pages (prefix caching).**  Pages are refcounted: ``_take`` hands
a page out at refcount 1, ``add_ref``/``decref`` adjust it, and a page
only returns to the free list when its count hits zero — ``release`` is a
decref over the lane's pages, so a prefix index (or another lane) holding
a reference keeps the KV resident after the original request finishes.
``alloc_prefill(..., shared_full=, shared_len=)`` maps an already-cached
prefix into a new lane's table instead of allocating fresh pages for it.
The invariant "never write into a page another holder can still read"
is enforced by **copy-on-write**: any write path about to touch a page
with refcount > 1 (the tail of a partially-shared page at admission, or
a decode write into a page the prefix index pinned) first repoints the
lane's table row at a fresh page and records a ``(src, dst)`` pair in
``pending_copies``; the engine materializes those as page-granular device
copies via ``apply_pending(cache)`` before its next dispatch.  Bookkeeping
(table rows, refcounts) commits immediately — only the bulk KV copy is
deferred to batch with the dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import PagedLayout, cdiv, paged_layout_for


class PagedKVPool:
    """Free-page list + per-lane page tables over a shared device pool.

    **Mesh-native pools** (``mesh=...``): the physical pools are laid out
    across the mesh by ``distributed.compressed_pspecs.serving_cache_shardings``
    — each ``model``-axis shard owns a slice of the pages axis (the
    sequence-sharding analogue; ``kv_shard="feature"`` shards the trailing
    feature dim instead) while the page tables stay **replicated**, so every
    shard resolves logical→physical page addresses locally.  Table sync is
    still incremental, but each upload/row-scatter is a *per-shard*
    ``device_put``: the replicated ``NamedSharding`` fans the dirty rows out
    to every device, and the scatter onto the resident (committed) arrays
    keeps their sharding.  Allocation policy is unchanged — page ids are
    global, the host allocator doesn't know or care which shard physically
    backs a page.
    """

    def __init__(
        self,
        model,
        *,
        max_batch: int,
        max_len: int,
        num_pages: int,
        page_size: int = 16,
        dtype=None,
        lookahead: int = 1,
        mesh=None,
        kv_shard: str = "seq",
        quant: bool = False,
    ):
        shards = 1
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            shards = int(sizes.get("model", 1))
        self.layout: PagedLayout = paged_layout_for(
            model.cfg, max_len, page_size=page_size, num_pages=num_pages,
            lookahead=lookahead, shards=shards, quant=quant,
        )
        self.mesh = mesh
        self.kv_shard = kv_shard
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = model.init_cache(max_batch, max_len, dtype, layout=self.layout)
        self._table_shardings: Optional[dict] = None
        # the engine reuses this tree for its executables' in/out shardings
        self.cache_shardings: Optional[dict] = None
        if mesh is not None:
            from repro.distributed.compressed_pspecs import (
                check_kv_shard,
                serving_cache_shardings,
            )

            check_kv_shard(mesh, kv_shard)
            shd = serving_cache_shardings(
                mesh, self.cache, self.layout, kv_shard=kv_shard
            )
            self.cache = jax.device_put(self.cache, shd)
            self.cache_shardings = shd
            self._table_shardings = shd.get("tables")
        lo = self.layout
        self._pt_full = np.full((max_batch, lo.pages_full), lo.sentinel, np.int32)
        self._pt_win = np.full((max_batch, lo.pages_win), lo.sentinel, np.int32)
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        # per-lane bookkeeping: logical page no. -> page id
        self._full_pages: list[dict[int, int]] = [dict() for _ in range(max_batch)]
        self._win_pages: list[dict[int, int]] = [dict() for _ in range(max_batch)]
        self._dirty_lanes: set[int] = set(range(max_batch))
        self._dev_tables: Optional[dict] = None
        # page refcounts: 0 = free, 1 = privately owned, >1 = shared (a
        # lane plus the prefix index and/or other lanes).  used/free page
        # accounting is unchanged — a page is "used" while its count > 0.
        self._ref = np.zeros(num_pages, np.int32)
        # (src, dst) page pairs whose bulk KV copy is still pending; the
        # engine drains these via apply_pending(cache) before dispatching.
        # Each pending src holds one extra ref until the copy lands.
        self.pending_copies: list[tuple[int, int]] = []
        self.evicted_pages = 0  # whole pages freed by window sliding
        self.cow_copies = 0  # copy-on-write page forks
        # sync accounting (serve_bench host-overhead reporting)
        self.table_full_uploads = 0  # whole-table device uploads
        self.table_row_syncs = 0  # dirty rows scattered incrementally
        self.table_syncs = 0  # device_tables calls that moved any data

    # -- accounting ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.layout.num_pages - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Pages currently held by more than one reference."""
        return int((self._ref > 1).sum())

    def _win_span_pages(self, length: int) -> int:
        """Distinct pages covering the live window of a length-`length` seq."""
        if not self.layout.win or length <= 0:
            return 0
        ps = self.layout.page_size
        start = max(0, length - self.layout.win)
        return (length - 1) // ps - start // ps + 1

    def prefill_pages(self, prompt_len: int) -> int:
        """Pages a prompt needs *through its first decode write* at
        position ``prompt_len`` — reserving the next-write page up front
        keeps ``ensure_steps`` from preempting a freshly prefilled lane
        (which would waste the whole batched prefill)."""
        ps = self.layout.page_size
        boundary = 1 if prompt_len % ps == 0 else 0  # pos prompt_len opens a page
        full = (cdiv(prompt_len, ps) + boundary) if self.layout.has_full else 0
        win = self._win_span_pages(prompt_len)
        if self.layout.win:
            win += boundary
        return full + win

    def pages_for_request(self, cache_len_cap: int) -> int:
        """Worst-case concurrent pages over a request's whole lifetime."""
        ps = self.layout.page_size
        full = cdiv(cache_len_cap, ps) if self.layout.has_full else 0
        win = min(cdiv(cache_len_cap, ps), self.layout.pages_win)
        return full + (win if self.layout.win else 0)

    def live_tokens(self, lane_lens: dict[int, int]) -> int:
        """Cache tokens actually referenced, for utilization reporting."""
        tot = 0
        for length in lane_lens.values():
            if self.layout.has_full:
                tot += length
            if self.layout.win:
                tot += min(length, self.layout.win)
        return tot

    # -- allocation ----------------------------------------------------------

    def can_admit(self, prompt_len: int, shared_len: int = 0) -> bool:
        return self.fresh_prefill_pages(prompt_len, shared_len) <= len(self._free)

    def fresh_prefill_pages(self, prompt_len: int, shared_len: int = 0) -> int:
        """Fresh pages an admission needs when the first ``shared_len``
        prompt tokens are already backed by cached pages.  A mid-page
        shared boundary costs one extra page: the shared partial page is
        copy-on-write forked so the lane can write its tail."""
        if shared_len <= 0:
            return self.prefill_pages(prompt_len)
        ps = self.layout.page_size
        n_shared = cdiv(shared_len, ps)
        cow = 1 if shared_len % ps else 0
        return self.prefill_pages(prompt_len) - n_shared + cow

    def _take(self) -> int:
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    def add_ref(self, pid: int) -> None:
        """Pin a live page (prefix index / shared-prefix admission)."""
        assert self._ref[pid] > 0, f"add_ref on free page {pid}"
        self._ref[pid] += 1

    def decref(self, pid: int) -> None:
        """Drop one reference; the page frees when the count hits zero."""
        assert self._ref[pid] > 0, f"decref on free page {pid}"
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)

    def _cow_full(self, lane: int, pg: int) -> None:
        """Fork a shared full-table page the lane is about to write: map a
        fresh page in its place and queue the page-granular device copy.
        The source keeps one extra ref until ``apply_pending`` lands the
        copy (so it cannot be reallocated and overwritten first)."""
        src = self._full_pages[lane][pg]
        dst = self._take()
        self._ref[src] += 1  # pending-copy pin
        self.pending_copies.append((src, dst))
        self.cow_copies += 1
        self._full_pages[lane][pg] = dst
        self._pt_full[lane, pg] = dst
        self._dirty_lanes.add(lane)
        self.decref(src)  # the lane's own claim moves to dst

    def alloc_prefill(
        self,
        lane: int,
        prompt_len: int,
        shared_full: tuple[int, ...] = (),
        shared_len: int = 0,
        defer_win: bool = False,
    ) -> bool:
        """Map every page the prompt's cache entries land in, plus the page
        backing the first decode write at ``prompt_len``; False if short.

        ``shared_full`` maps already-cached pages (from the engine's prefix
        index) at logical full-table pages ``0..len(shared_full)-1`` —
        each gains a reference instead of coming off the free list, and
        only the uncached tail allocates fresh pages.  ``shared_len`` is
        the token length the shared pages cover; when it ends mid-page the
        last shared page is copy-on-write forked (the lane's tail prefill
        writes into it).  Shared prefixes require a full (append-only)
        table — windowed layouts evict pages, so the engine never offers
        them a shared prefix.

        No window eviction happens here: the prefill still scatters into
        the oldest window page, so it must stay mapped until the first
        ``ensure_steps`` (whose eviction runs after the prefill wrote).

        ``defer_win=True`` (windowed *chunked* prefill) maps no window
        pages at all: each chunk's pages are mapped just before its
        dispatch via ``ensure_steps(lane, start, csz)``, which also evicts
        pages the window slid past — the whole point of chunking a long
        windowed prompt is never holding its full page span at once."""
        assert not shared_full or (self.layout.has_full and not self.layout.win)
        assert shared_len < prompt_len or not shared_full
        if self.fresh_prefill_pages(prompt_len, shared_len) > len(self._free):
            return False
        lo, ps = self.layout, self.layout.page_size
        next_pg = prompt_len // ps  # page of the first decode write
        if lo.has_full:
            for pg, pid in enumerate(shared_full):
                self.add_ref(pid)
                self._full_pages[lane][pg] = pid
                self._pt_full[lane, pg] = pid
            if shared_full and shared_len % ps:
                self._cow_full(lane, len(shared_full) - 1)
            for pg in range(cdiv(prompt_len, ps)):
                if pg in self._full_pages[lane]:
                    continue
                pid = self._take()
                self._full_pages[lane][pg] = pid
                self._pt_full[lane, pg] = pid
            if next_pg not in self._full_pages[lane]:
                pid = self._take()
                self._full_pages[lane][next_pg] = pid
                self._pt_full[lane, next_pg] = pid
        if lo.win and prompt_len > 0 and not defer_win:
            start = max(0, prompt_len - lo.win)
            for pg in range(start // ps, (prompt_len - 1) // ps + 1):
                pid = self._take()
                self._win_pages[lane][pg] = pid
                self._pt_win[lane, pg % lo.pages_win] = pid
            if next_pg not in self._win_pages[lane]:
                pid = self._take()
                self._win_pages[lane][next_pg] = pid
                self._pt_win[lane, next_pg % lo.pages_win] = pid
        self._dirty_lanes.add(lane)
        return True

    def prompt_pages(
        self, lane: int, length: int
    ) -> tuple[list[int], Optional[int]]:
        """The full-table pages backing a lane's first ``length`` cached
        tokens, for prefix-index insertion: ``(complete_page_ids,
        partial_tail_id)`` where the tail id (None when ``length`` is
        page-aligned) holds only ``length % page_size`` valid tokens."""
        ps = self.layout.page_size
        n_full = length // ps
        full = [self._full_pages[lane][pg] for pg in range(n_full)]
        tail = self._full_pages[lane].get(n_full) if length % ps else None
        return full, tail

    def ensure_steps(self, lane: int, pos: int, k: int = 1) -> bool:
        """Back the next ``k`` decode writes at ``pos..pos+k-1``; False =
        pool full (nothing is allocated on failure — all-or-nothing, so a
        preemption retry sees the pool unchanged).

        Reserving the whole dispatch up front is what makes the K-step
        fused decode safe: the scan cannot run out of pages mid-flight, so
        the host only ever preempts at dispatch boundaries.  Also evicts
        whole window pages the sliding window has moved past *as of the
        first write* (eager, so another lane can claim them this very
        dispatch; pages expiring mid-scan are reclaimed at the next
        boundary).
        """
        lo, ps = self.layout, self.layout.page_size
        if lo.win:
            self._evict_win(lane, pos)
        k = max(1, min(k, self.max_len - pos))  # writes past max_len freeze
        pages = range(pos // ps, (pos + k - 1) // ps + 1)
        need_full = [
            pg for pg in pages if lo.has_full and pg not in self._full_pages[lane]
        ]
        # mapped pages the dispatch will write that another holder (the
        # prefix index, or a forked lane) can still read: copy-on-write
        # them, which costs one fresh page each
        cow_full = [
            pg
            for pg in pages
            if lo.has_full
            and pg in self._full_pages[lane]
            and self._ref[self._full_pages[lane][pg]] > 1
        ]
        need_win = [
            pg for pg in pages if lo.win and pg not in self._win_pages[lane]
        ]
        if len(need_full) + len(cow_full) + len(need_win) > len(self._free):
            return False
        for pg in cow_full:
            self._cow_full(lane, pg)
        for pg in need_full:
            pid = self._take()
            self._full_pages[lane][pg] = pid
            self._pt_full[lane, pg] = pid
            self._dirty_lanes.add(lane)
        for pg in need_win:
            pid = self._take()
            self._win_pages[lane][pg] = pid
            self._pt_win[lane, pg % lo.pages_win] = pid
            self._dirty_lanes.add(lane)
        return True

    def _evict_win(self, lane: int, pos: int) -> None:
        lo, ps = self.layout, self.layout.page_size
        start = max(0, pos - lo.win + 1)  # oldest live position after this write
        expired = [pg for pg in self._win_pages[lane] if (pg + 1) * ps - 1 < start]
        for pg in expired:
            pid = self._win_pages[lane].pop(pg)
            self.decref(pid)
            self.evicted_pages += 1
            if self._pt_win[lane, pg % lo.pages_win] == pid:
                self._pt_win[lane, pg % lo.pages_win] = lo.sentinel
            self._dirty_lanes.add(lane)

    def rollback(self, lane: int, new_len: int) -> None:
        """Truncate a lane's committed length to ``new_len`` after a
        speculative round: full-table pages past the one backing the next
        write (logical page ``new_len // page_size`` — kept, exactly the
        page ``alloc_prefill``/``ensure_steps`` keep mapped ahead of the
        write cursor) are *dereferenced*, not force-freed, so a shared
        prefix-cache page (or a COW fork another lane still reads) is
        never clobbered by rejected drafts — its other holders keep it
        resident and only this lane's claim drops.

        The speculative reservation this unwinds is a plain
        ``ensure_steps(lane, pos, gamma + 1)``: all-or-nothing, so a
        rejected tail can always be rolled back without the pool ever
        having been over-committed mid-round.  The device-side half of the
        truncation is the verify dispatch rewriting ``cache["len"]`` —
        stale KV past it is dead under the length masks every layout view
        applies, so no page contents need scrubbing.  Pages with a pending
        COW copy *into* them are skipped defensively (the engine drains
        ``pending_copies`` before any speculative dispatch, so none should
        exist here).  Windowed tables have no speculative seam (the engine
        gates ``spec_gamma`` off windowed archs) and are left untouched.
        """
        lo, ps = self.layout, self.layout.page_size
        if not lo.has_full:
            return
        keep = new_len // ps  # page of the next decode write stays mapped
        pend_dst = {d for _, d in self.pending_copies}
        for pg in [p for p in self._full_pages[lane] if p > keep]:
            pid = self._full_pages[lane][pg]
            if pid in pend_dst:
                continue
            del self._full_pages[lane][pg]
            self.decref(pid)
            if self._pt_full[lane, pg] == pid:
                self._pt_full[lane, pg] = lo.sentinel
            self._dirty_lanes.add(lane)

    def release(self, lane: int) -> None:
        """Drop the lane's reference on every page it holds (request
        finished or preempted).  Pages the prefix index (or a forked lane)
        still references stay resident; privately-held pages free."""
        for pg, pid in self._full_pages[lane].items():
            self.decref(pid)
        for pg, pid in self._win_pages[lane].items():
            self.decref(pid)
        if self._full_pages[lane] or self._win_pages[lane]:
            self._dirty_lanes.add(lane)
        self._full_pages[lane] = {}
        self._win_pages[lane] = {}
        self._pt_full[lane, :] = self.layout.sentinel
        self._pt_win[lane, :] = self.layout.sentinel

    # -- staged admissions (device-resident refill) --------------------------
    #
    # The device-resident scheduler swaps a queued request into a freed
    # lane *inside* the decode loop: the host pre-builds complete table
    # rows ("staged rows") with fresh pages backing every position the
    # device could write before the next host sync point, ships them as a
    # loop operand, and the in-loop refill copies a staged row over the
    # lane's row.  Staged pages are ordinary refcounted pages (off the
    # free list at count 1) that no lane's table references yet; on the
    # host-side replay of a consumed refill, ``adopt_staged`` installs the
    # row as the lane's mirror, and an unconsumed stage is returned via
    # ``release_staged``.

    def _stage_exposure(self, prompt_len: int, budget: int, horizon: int) -> int:
        """Positions ``0..e-1`` a staged request's refill may write before
        the host next reconciles: one scheduling cycle's worth of steps
        (``horizon``), capped by the request's own freeze point."""
        cap = min(self.max_len, prompt_len + max(1, budget))
        return min(max(1, horizon), cap)

    def staged_pages(self, prompt_len: int, budget: int, horizon: int) -> int:
        """Fresh pages one staged admission reserves."""
        lo = self.layout
        n = cdiv(self._stage_exposure(prompt_len, budget, horizon), lo.page_size)
        return n * ((1 if lo.has_full else 0) + (1 if lo.win else 0))

    def stage_alloc(
        self, prompt_len: int, budget: int, horizon: int
    ) -> Optional[dict]:
        """Reserve pages + build sentinel-padded table rows for a staged
        request; ``None`` when the pool is short (all-or-nothing).

        The returned record is host-only bookkeeping (numpy rows + page
        maps) — no lane's table row or device array is touched, so staging
        is safe while dispatches are in flight.
        """
        lo, ps = self.layout, self.layout.page_size
        if self.staged_pages(prompt_len, budget, horizon) > len(self._free):
            return None
        e = self._stage_exposure(prompt_len, budget, horizon)
        rec: dict = {
            "full_row": None, "win_row": None,
            "full_pages": {}, "win_pages": {}, "exposure": e,
        }
        if lo.has_full:
            row = np.full(lo.pages_full, lo.sentinel, np.int32)
            for pg in range(cdiv(e, ps)):
                pid = self._take()
                rec["full_pages"][pg] = pid
                row[pg] = pid
            rec["full_row"] = row
        if lo.win:
            row = np.full(lo.pages_win, lo.sentinel, np.int32)
            for pg in range(cdiv(e, ps)):
                pid = self._take()
                rec["win_pages"][pg] = pid
                row[pg % lo.pages_win] = pid
            rec["win_row"] = row
        return rec

    def release_staged(self, rec: dict) -> None:
        """Return an unconsumed stage's pages (request went back to the
        queue for a normal host admission)."""
        for pid in rec["full_pages"].values():
            self.decref(pid)
        for pid in rec["win_pages"].values():
            self.decref(pid)

    def adopt_staged(self, lane: int, rec: dict) -> None:
        """Install a consumed stage as ``lane``'s mappings (host replay of
        an in-loop refill).  The device's loop already holds exactly this
        row for the lane, and ``release`` of the lane's previous request
        already marked it dirty — the next sync rewrites identical values,
        which is harmless."""
        assert not self._full_pages[lane] and not self._win_pages[lane], (
            f"adopt_staged into occupied lane {lane}"
        )
        self._full_pages[lane] = dict(rec["full_pages"])
        self._win_pages[lane] = dict(rec["win_pages"])
        if rec["full_row"] is not None:
            self._pt_full[lane, :] = rec["full_row"]
        if rec["win_row"] is not None:
            self._pt_win[lane, :] = rec["win_row"]
        self._dirty_lanes.add(lane)

    # -- copy-on-write materialization ---------------------------------------

    _POOL_LEAVES = ("k", "v", "ckv", "krope")

    def apply_pending(self, cache: dict) -> dict:
        """Materialize queued copy-on-write forks as page-granular device
        copies on ``cache`` and return the updated tree.

        Must run on the *live* cache (the engine re-binds its tree from
        every donated executable, so the pool's own ``self.cache`` handle
        goes stale) before any dispatch that could write a forked page.
        Every paged pool leaf — KV arrays and their quantization scales —
        copies rows ``src → dst`` in one batched gather/scatter; chained
        pairs (a dst later re-forked as a src) fall back to per-pair order.
        Sources drop their pending pin afterwards, freeing any whose last
        reader was the fork itself."""
        if not self.pending_copies:
            return cache
        pairs = self.pending_copies
        self.pending_copies = []
        srcs = [s for s, _ in pairs]
        dsts = [d for _, d in pairs]
        chained = bool(set(srcs) & set(dsts))
        batches = [(s, d) for s, d in pairs] if chained else [(srcs, dsts)]

        def copy_rows(arr, stacked):
            for s, d in batches:
                si, di = jnp.asarray(s), jnp.asarray(d)
                arr = (
                    arr.at[:, di].set(arr[:, si])
                    if stacked
                    else arr.at[di].set(arr[si])
                )
            return arr

        def walk(node, shd, stacked):
            out = {}
            for name, v in node.items():
                if isinstance(v, dict):
                    sub = shd.get(name) if isinstance(shd, dict) else None
                    out[name] = walk(v, sub, stacked or name == "body")
                elif (
                    name in self._POOL_LEAVES or name.endswith("_scale")
                ) and hasattr(v, "ndim"):
                    nv = copy_rows(v, stacked)
                    if isinstance(shd, dict) and name in shd:
                        # eager scatters may drop the NamedSharding; re-pin
                        nv = jax.device_put(nv, shd[name])
                    out[name] = nv
                else:
                    out[name] = v
            return out

        cache = walk(cache, self.cache_shardings, False)
        for s in srcs:
            self.decref(s)
        return cache

    # -- device view ---------------------------------------------------------

    def device_tables(self) -> dict:
        """The page tables as device arrays, synced *incrementally*.

        The arrays are already in *kernel layout*: contiguous ``(max_batch,
        n_slots)`` int32 with the out-of-bounds sentinel ``num_pages`` in
        every unmapped slot — exactly the operand ``kernels.paged_attn``
        scalar-prefetches to compute page addresses, and the same array the
        gathered reference path indexes.  The first call uploads the whole
        tables once; after that only *dirty lanes* (rows touched since the
        last sync) are scattered into the resident device arrays — the
        steady-state decode dispatch moves ``O(changed rows)`` bytes, not
        ``O(max_batch × n_slots)``.
        """
        if self._dev_tables is None:
            t = {}
            put = (
                (lambda a, k: jax.device_put(a, self._table_shardings[k]))
                if self._table_shardings is not None
                else (lambda a, k: jnp.asarray(a))
            )
            if self.layout.pages_full:
                t["full"] = put(self._pt_full, "full")
            if self.layout.pages_win:
                t["win"] = put(self._pt_win, "win")
            self._dev_tables = t
            self._dirty_lanes.clear()
            self.table_full_uploads += 1
            self.table_syncs += 1
            # pre-compile every padded scatter shape (no-op scatters of row
            # 0 onto itself): the first dirty-row sync otherwise pays a
            # trace+compile inside a *timed* host-scheduling window, which
            # dominates short benches
            pad = 1
            while pad <= self.max_batch:
                self._scatter_rows(t, [0] * min(pad, self.max_batch))
                pad *= 2
            return self._dev_tables
        if self._dirty_lanes:
            rows = sorted(self._dirty_lanes)
            n_dirty = len(rows)
            # pad the row list to the next power of two (duplicate indices
            # rewrite identical rows) so every dirty count ≤ max_batch
            # reuses one of O(log max_batch) compiled scatter shapes
            pad = 1
            while pad < n_dirty:
                pad *= 2
            rows = rows + [rows[0]] * (pad - n_dirty)
            self._dev_tables = self._scatter_rows(dict(self._dev_tables), rows)
            self._dirty_lanes.clear()
            self.table_row_syncs += n_dirty
            self.table_syncs += 1
        return self._dev_tables

    def _scatter_rows(self, t: dict, rows: list) -> dict:
        idx = jnp.asarray(rows, jnp.int32)
        if self.layout.pages_full:
            t["full"] = t["full"].at[idx].set(jnp.asarray(self._pt_full[rows]))
        if self.layout.pages_win:
            t["win"] = t["win"].at[idx].set(jnp.asarray(self._pt_win[rows]))
        return t

    def adopt_tables(self, tables: Optional[dict]) -> None:
        """Re-anchor the incremental sync on the arrays a jitted call
        returned.  Required after any executable that *donates* the cache:
        the buffers ``device_tables`` last scattered into were consumed by
        the donation, and the returned (aliased) arrays are the live ones.
        Dirty lanes accumulated since remain dirty — they scatter onto the
        adopted arrays at the next sync."""
        if tables:
            self._dev_tables = dict(tables)
