"""Radix prompt index over refcounted KV pages (prefix caching).

``PrefixIndex`` maps token prefixes to the pool pages that already hold
their KV, at page granularity: each trie edge is one *full* page of
``page_size`` prompt tokens (keyed by the exact token tuple), and a node
may additionally carry *partial* entries — tail pages whose first
``n_valid < page_size`` slots hold prompt KV.  Admission asks ``match``
for a new prompt's longest cached prefix; the engine then maps the hit
pages into the lane's table (``PagedKVPool.alloc_prefill(shared_full=...)``)
and chunk-prefills only the uncached tail.

Every indexed page carries one pool reference (``add_ref`` on insert,
``decref`` on evict), so indexed KV stays resident after the request that
produced it finishes — this is what turns the pool into a cross-request
cache.  Sharing is read-only: a forked lane that must write into a
matched partial page copy-on-write forks it in the pool, and the *owner*
of an indexed partial page forks on its first decode write for the same
reason — the index never observes a mutation.

Matching is capped at ``len(prompt) - 1`` tokens: at least one prompt
token must run through the model so the first sampled token has logits.

Correctness does not depend on eviction policy; ``evict`` drops
least-recently-used leaves first (partial entries, then childless full
nodes) and reports how many pages actually returned to the free list
(an entry whose page a live lane still references frees nothing yet).
"""
from __future__ import annotations

from typing import Optional, Sequence


class _Node:
    """One full page of cached prompt: ``toks`` (page_size tokens) → pid."""

    __slots__ = ("pid", "toks", "children", "partials", "parent", "last_used")

    def __init__(self, pid: int, toks: tuple, parent: "Optional[_Node]"):
        self.pid = pid
        self.toks = toks
        self.children: dict[tuple, _Node] = {}
        self.partials: list[_Partial] = []
        self.parent = parent
        self.last_used = 0


class _Partial:
    """A tail page: only the first ``len(toks)`` slots hold prompt KV."""

    __slots__ = ("pid", "toks", "last_used")

    def __init__(self, pid: int, toks: tuple):
        self.pid = pid
        self.toks = toks
        self.last_used = 0


class PrefixIndex:
    def __init__(self, pool, page_size: int):
        self.pool = pool
        self.ps = page_size
        self.root = _Node(-1, (), None)
        self._tick = 0
        self.pages = 0  # entries currently indexed (== pool refs held)
        self.lookups = 0
        self.hits = 0  # lookups that matched >= 1 page
        self.hit_tokens = 0
        self.evictions = 0  # entries dropped by evict()

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        while node is not None:
            node.last_used = self._tick
            node = node.parent

    # -- lookup --------------------------------------------------------------

    def match(self, prompt: Sequence[int]) -> tuple[int, tuple[int, ...]]:
        """Longest cached prefix of ``prompt``: ``(matched_len, page_ids)``.

        ``page_ids`` back logical full-table pages ``0..len(page_ids)-1``;
        when ``matched_len % page_size != 0`` the last id is a partial
        entry (the caller copy-on-write forks it before writing its tail).
        """
        self.lookups += 1
        prompt = tuple(prompt)
        cap = len(prompt) - 1  # >= 1 token must prefill for first logits
        node, pids, matched = self.root, [], 0
        while matched + self.ps <= cap:
            child = node.children.get(prompt[matched:matched + self.ps])
            if child is None:
                break
            node = child
            pids.append(child.pid)
            matched += self.ps
        best: Optional[_Partial] = None
        for p in node.partials:
            n = len(p.toks)
            if matched + n <= cap and prompt[matched:matched + n] == p.toks:
                if best is None or n > len(best.toks):
                    best = p
        if best is not None:
            self._tick += 1
            best.last_used = self._tick
            pids.append(best.pid)
            matched += len(best.toks)
        if pids:
            self._touch(node)
            self.hits += 1
            self.hit_tokens += matched
        return (matched, tuple(pids)) if pids else (0, ())

    # -- insertion -----------------------------------------------------------

    def insert(self, prompt: Sequence[int], full_pids: Sequence[int],
               partial_pid: Optional[int], partial_len: int) -> None:
        """Index a fully-prefilled prompt's pages.

        ``full_pids[i]`` backs prompt tokens ``[i*ps, (i+1)*ps)``;
        ``partial_pid`` (if given) holds the trailing ``partial_len``
        tokens.  Pages already indexed (a forked lane re-inserting its
        shared prefix, or a duplicate prompt racing in) are skipped — the
        first entry wins and keeps its single reference."""
        prompt = tuple(prompt)
        node = self.root
        for i, pid in enumerate(full_pids):
            key = prompt[i * self.ps:(i + 1) * self.ps]
            child = node.children.get(key)
            if child is None:
                self.pool.add_ref(pid)
                child = _Node(pid, key, node)
                node.children[key] = child
                self.pages += 1
            node = child
        self._touch(node)
        if partial_pid is None or partial_len <= 0:
            return
        toks = prompt[len(full_pids) * self.ps:
                      len(full_pids) * self.ps + partial_len]
        for key in node.children:
            if key[:partial_len] == toks:
                return  # a full page already covers these tokens
        for p in node.partials:
            if len(p.toks) >= partial_len and p.toks[:partial_len] == toks:
                p.last_used = self._tick
                return  # an equal-or-longer partial subsumes the new one
        # the new entry dominates any shorter partial it extends
        for p in list(node.partials):
            if toks[:len(p.toks)] == p.toks:
                node.partials.remove(p)
                self.pool.decref(p.pid)
                self.pages -= 1
                self.evictions += 1
        self.pool.add_ref(partial_pid)
        p = _Partial(partial_pid, toks)
        p.last_used = self._tick
        node.partials.append(p)
        self.pages += 1

    # -- eviction ------------------------------------------------------------

    def _leaves(self) -> list[tuple]:
        """Evictable entries: ``(last_used, parent, partial, full_node)``
        with exactly one of partial / full_node set."""
        out: list[tuple] = []

        def walk(node: _Node):
            for p in node.partials:
                out.append((p.last_used, node, p, None))
            for c in node.children.values():
                if not c.children and not c.partials:
                    out.append((c.last_used, node, None, c))
                else:
                    walk(c)

        walk(self.root)
        return out

    def evict(self, want_free: int = 1) -> int:
        """Drop LRU leaf entries until ``want_free`` pages actually
        returned to the free list (or the index is empty); returns the
        number freed.  Dropping an entry whose page a live lane still
        references releases the index's pin without freeing — progress is
        still made, because the next drop candidates surface."""
        freed = 0
        while freed < want_free:
            leaves = self._leaves()
            if not leaves:
                break
            leaves.sort(key=lambda e: e[0])
            dropped_any = False
            for _, parent, part, full in leaves:
                if freed >= want_free:
                    break
                if full is not None:  # childless full node
                    del parent.children[full.toks]
                    pid = full.pid
                else:
                    parent.partials.remove(part)
                    pid = part.pid
                before = self.pool.free_pages
                self.pool.decref(pid)
                freed += self.pool.free_pages - before
                self.pages -= 1
                self.evictions += 1
                dropped_any = True
            if not dropped_any:
                break
        return freed

    def clear(self) -> None:
        """Drop every entry (and its pool reference)."""
        def walk(node: _Node):
            for p in node.partials:
                self.pool.decref(p.pid)
                self.pages -= 1
            node.partials = []
            for c in list(node.children.values()):
                walk(c)
                self.pool.decref(c.pid)
                self.pages -= 1
            node.children = {}

        walk(self.root)
