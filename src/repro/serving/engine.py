"""Continuous-batching decode engine over a (compressed or dense) model tree.

The engine is the serving counterpart of ``launch/train.py``'s Trainer: it
owns the KV-cache (a per-lane slab, or a block-granular paged pool — see
below), a FIFO request queue, and the jitted prefill/decode executables,
and it serves the parameter tree it is given *as is*.  Hand it the
N:M-compressed artifact from ``sparse_infer.compress_params`` and every
weight matmul inside prefill / decode routes through the compressed
``nm_spmm`` path (see ``models.layers.matmul``) — the dense weights never
materialize in HBM.

Scheduling: dispatch-boundary continuous batching
-------------------------------------------------
The decode hot loop is **fused and zero-copy**: one jitted dispatch runs
``steps_per_dispatch`` (K) decode steps as an on-device ``lax.scan`` that
embeds, attends, samples, scatters each new token into the cache, and
advances ``cache["len"]`` — K tokens per lane move device→host as a single
``(K, max_batch)`` block, so the host is consulted once per K tokens
instead of once per token.  The cache pytree and token buffer are
**donated** (``donate_argnums``) into the decode, prefill, and
chunked-prefill executables, so XLA updates the paged pool in place
instead of copying every cache buffer per call; pass ``donate=False`` for
the copying baseline (bit-identical streams, strictly more HBM traffic).

All scheduling happens at **dispatch boundaries**: queued requests are
admitted (batched bucketed prefill), finished lanes retire, and — under
pool pressure — preemption victims are chosen, only between dispatches.
Mid-scan, per-lane stop detection runs **on device**
(``sampling.advance_stops``): a lane that emits its EOS, exhausts its
``max_new_tokens`` budget, or hits the logical capacity freezes (stops
sampling, stops writing, stops advancing its length) until the host
replays the same rules over the token block at the boundary.  The paged
pool pre-reserves every page the K writes need (``ensure_steps``) before
the dispatch, so mid-scan pool exhaustion cannot occur.  Per-slot
``cache["len"]`` keeps heterogeneous sequence positions correct; idle
lanes are pinned to length 0 and their sampled tokens discarded.

**Chunked prefill** (``prefill_chunk=N``): a prompt longer than N tokens
no longer head-of-line-blocks in-flight decodes behind one monolithic
prefill — it is absorbed N tokens at a time, one chunk per scheduling
step, interleaved with the decode dispatches of the running lanes; the
final chunk samples the request's first token and the lane joins the next
decode dispatch.  Attention-family archs only (recurrent state cannot
resume mid-prompt; sliding-window archs keep whole-prompt prefill).

Cache layouts
-------------
``DecodeEngine`` runs over either cache layout behind the
``models.cache.CacheLayout`` seam:

- **slab** (default): one contiguous ``(max_batch, max_len, ...)`` slab per
  attention/MLA layer.  Admission = a free lane; a request that outgrows
  ``max_len`` finishes with ``finish_reason="cache_full"``.
- **paged** (pass ``num_pages``/``page_size`` or a prebuilt
  ``kv_pool.PagedKVPool``): each layer owns a ``(num_pages, page_size, ...)``
  pool and per-lane *page tables* map logical token positions to physical
  pages.  Admission requires a free lane *and* enough free pages for the
  prompt; page tables grow on demand as lanes decode, and the device copy
  is synced **incrementally** — only lanes whose rows changed since the
  last dispatch are scattered into the resident table arrays
  (``PagedKVPool.device_tables``), never a full re-upload per step.  When
  the pool runs dry at a dispatch boundary the engine **preempts** the
  youngest lane instead of truncating: its pages are freed, and the
  request is re-queued at the front with its generated-so-far tokens as a
  resume prefix — on re-admission it re-prefills ``prompt + prefix`` and
  continues.  ``finish_reason="cache_full"`` survives only for the logical
  per-request capacity ``max_len`` (the page-table width), never for pool
  pressure.  The host-side allocator lives in ``serving.kv_pool``.

Prefill is **bucketed and batched**: queued prompts admitted in the same
scheduling step are padded to a small static set of bucket lengths (powers
of two up to ``max_len`` by default) and each bucket group is prefilled in
one jitted call.  Architectures with recurrent state (SSM / RG-LRU) cannot
absorb padding tokens into their state, so they group by *exact* prompt
length instead — still one batched prefill per group.  Chunked prefill is
batched the same way: one chunk dispatch per scheduling step absorbs a
chunk of *every* currently-chunking lane.

Mesh-native serving
-------------------
Pass ``mesh=`` (a ``("data", "model")`` mesh, e.g. from
``launch.mesh.make_local_mesh``) and the engine becomes tensor-parallel
end to end: every executable — prefill, chunked prefill, and the K-step
decode scan — is jitted with **explicit in/out NamedShardings**, and the
live params / cache / token buffer are ``device_put`` to match, so GSPMD
partitions the whole serving path instead of replicating it.

- **Weights** are TP-sharded by the serving pspec seam
  (``distributed.compressed_pspecs``): dense leaves follow the training
  rules with FSDP off (decode reads every weight each step), and each
  ``CompressedTensor`` leaf derives its spec from the dense rule for the
  same name — TP on the non-compressed (output) dim by default, on the
  compressed (reduction) dim only when the dense dim divides by
  ``M × axis_size`` so no N:M group straddles a shard.  Per-leaf
  ``sanitize_spec`` degrades odd dims to replication instead of erroring.
  The compressed artifact is served *sharded*: no dense or
  fully-replicated weight leaf is ever materialized (inspect with
  :meth:`sharding_report`).
- **KV caches are sequence-sharded** on the ``model`` axis
  (``kv_shard="seq"``, the ``cache_pspecs`` rule measured 75x cheaper in
  collectives than head-sharding): slab caches split the per-lane
  sequence axis; the paged pool splits its *pages* axis, so each shard
  physically owns a slice of the pool while the (replicated) page tables
  resolve logical→physical addresses locally on every shard.  Decode
  attention computes per-shard partial flash stats and the softmax
  combines via tiny psums — only ``(B, H)``-sized stats cross the
  interconnect, never cache pages.  The engine installs a
  ``kernels.dispatch.mesh_context`` around every executable call, so
  sharded pools (``PagedLayout.shards > 1``) route to the shard_map
  wrapper (``kernels.sharded``: per-shard table remap + the Pallas grid
  walk + an explicit flash-stat combine) whenever the inner route is a
  kernel body; the GSPMD-partitioned XLA gathered path remains the
  correctness backstop and the off-TPU default.  Reduction-TP'd
  compressed leaves are stamped with their shard count
  (``annotate_reduction_tp``) so ``nm_spmm`` takes the per-shard route
  the same way.  :meth:`kernel_route` reports the resolved route.
- **Degenerate 1×1 meshes are bit-identical** to the mesh-less engine:
  every sharding becomes trivial and the executables lower to the exact
  single-device programs, so ``mesh=None`` and a one-device mesh (and, in
  practice, any mesh shape — locked by tests/test_sharded_serving.py)
  produce the same greedy token streams.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import SlabLayout
from repro.models.model import TransformerLM, _block_mixer_mlp, layer_plan
from repro.serving.kv_pool import PagedKVPool
from repro.serving.sampling import SamplingParams, advance_stops, sample_tokens
from repro.sparse_infer.compress import CompressedTensor


@contextlib.contextmanager
def _quiet_donation():
    """Buffer donation is a no-op on backends without aliasing support
    (CPU); the stream is identical either way, so JAX's per-executable
    warning is noise — suppressed only around the engine's own dispatches
    (never globally: other code's donation bugs should still warn)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


@dataclasses.dataclass
class GenerationResult:
    """A completed request."""

    uid: int
    prompt: list[int]
    tokens: list[int]  # generated tokens (eos not included)
    finish_reason: str  # "eos" | "length" | "cache_full"


@dataclasses.dataclass
class _Request:
    uid: int
    prompt: list[int]
    sampling: SamplingParams
    # tokens generated before a preemption; on admission the engine
    # prefills prompt + prefix and generation continues after them
    prefix: list[int] = dataclasses.field(default_factory=list)


class _Slot:
    """Host-side bookkeeping for one active batch lane."""

    __slots__ = ("uid", "prompt", "sampling", "generated", "pos", "seq",
                 "pending")

    def __init__(self, req: _Request, pos: int, seq: int,
                 pending: Optional[list[int]] = None):
        self.uid = req.uid
        self.prompt = req.prompt
        self.sampling = req.sampling
        self.generated: list[int] = list(req.prefix)
        self.pos = pos  # host mirror of cache["len"][lane]
        self.seq = seq  # admission order; preemption evicts youngest first
        # chunked prefill: prompt(+prefix) tokens not yet absorbed into the
        # cache; the lane joins decode once this drains
        self.pending: list[int] = pending or []


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class DecodeEngine:
    """Batched decode over a slab or paged cache with continuous batching.

    Parameters
    ----------
    model: the ``TransformerLM`` wrapper.
    params: the serving tree — dense arrays and/or ``CompressedTensor``
        leaves; served directly, no rehydration.
    max_batch: number of concurrent decode lanes.
    max_len: logical per-request cache capacity (prompt + generated).
    kv_pool / num_pages / page_size: enable the paged layout — pass a
        prebuilt ``PagedKVPool`` or just ``num_pages`` (+ optional
        ``page_size``, default 16) to have the engine build one.
    steps_per_dispatch: decode steps fused into one on-device scan (K).
        The host syncs once per K tokens; admission/preemption happen at
        dispatch boundaries.  Greedy streams are bit-identical across K.
    donate: donate the cache pytree + token buffer into the jitted
        executables so the cache updates in place (no per-step full-cache
        copy).  ``False`` keeps the copying baseline; streams are
        bit-identical either way.
    prefill_chunk: absorb prompts longer than this in fixed-size chunks
        interleaved with decode dispatches (attention-family archs only;
        ignored for recurrent-state and sliding-window archs).
    prefill_buckets: static prompt-pad lengths for batched prefill
        (default: powers of two up to ``max_len``).  Ignored for archs
        with recurrent state, which group by exact prompt length.
    max_prefill_batch: cap on requests prefetched into one batched
        prefill (default ``max_batch``).
    mesh: optional ``("data", "model")`` mesh — serve tensor-parallel with
        sequence/pages-sharded KV caches (see "Mesh-native serving" in the
        module docstring).  A 1×1 mesh degenerates bit-identically to
        ``mesh=None``.
    kv_shard: ``"seq"`` (default; slab sequence axis / paged pages axis
        over ``model``) or ``"feature"`` (trailing head/latent dim) —
        the ``cache_pspecs`` layouts.  ``"feature"`` is rejected on
        meshes with a model axis > 1: its prefill write miscompiles under
        the SPMD partitioner (see ``compressed_pspecs.check_kv_shard``).
    prefix_cache: index every fully-prefilled prompt's pages in a radix
        trie (``serving.prefix_cache.PrefixIndex``); later requests
        sharing a prefix map the cached pages into their table (shared,
        refcounted, copy-on-write on divergence) and prefill only the
        uncached tail.  Paged + append-only + attention-family only;
        silently ignored (with a warning) otherwise.
    kv_quant: store KV pages as int8 with per-page-row scales (f16
        storage, f32 compute)
        (``models.cache.PagedLayout.quant``) — ~4x smaller pool at equal
        page count, dequantized inside the attention kernels.  Greedy
        streams may differ from fp pools within quantization tolerance.
    """

    def __init__(
        self,
        model: TransformerLM,
        params: Any,
        *,
        max_batch: int = 8,
        max_len: int = 128,
        seed: int = 0,
        kv_pool: Optional[PagedKVPool] = None,
        num_pages: Optional[int] = None,
        page_size: int = 16,
        steps_per_dispatch: int = 1,
        donate: bool = True,
        prefill_chunk: Optional[int] = None,
        prefill_buckets: Optional[Sequence[int]] = None,
        max_prefill_batch: Optional[int] = None,
        mesh=None,
        kv_shard: str = "seq",
        prefix_cache: bool = False,
        kv_quant: bool = False,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh
        self.kv_shard = kv_shard
        if steps_per_dispatch < 1:
            raise ValueError(f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
        self.steps_per_dispatch = steps_per_dispatch
        self.donate = donate
        if kv_pool is None and num_pages is not None:
            kv_pool = PagedKVPool(
                model, max_batch=max_batch, max_len=max_len,
                num_pages=num_pages, page_size=page_size,
                lookahead=steps_per_dispatch, mesh=mesh, kv_shard=kv_shard,
                quant=kv_quant,
            )
        if kv_quant and kv_pool is not None and not kv_pool.layout.quant:
            raise ValueError(
                "kv_quant=True needs a pool built with quant=True (pass "
                "quant= to PagedKVPool, or let the engine build it)"
            )
        self.pool = kv_pool
        if self.pool is not None:
            if self.pool.layout.lookahead < steps_per_dispatch:
                raise ValueError(
                    f"pool lookahead {self.pool.layout.lookahead} < "
                    f"steps_per_dispatch {steps_per_dispatch}; build the pool "
                    "with lookahead >= K"
                )
            if mesh is not None and (
                self.pool.mesh is not mesh
                or getattr(self.pool, "kv_shard", kv_shard) != kv_shard
            ):
                raise ValueError(
                    "a mesh-native engine needs a pool built with the same "
                    "mesh and kv_shard (pass mesh=/kv_shard= to PagedKVPool, "
                    "or let the engine build it via num_pages=...)"
                )
            self.layout = self.pool.layout
            self.cache = self.pool.cache
        else:
            self.layout = SlabLayout(max_len)
            self.cache = model.init_cache(max_batch, max_len)
        # mesh-native serving: every executable below is jitted with explicit
        # in/out NamedShardings derived from the serving pspec seam, and the
        # live params / cache / token buffer are device_put to match.  A 1x1
        # mesh makes every sharding trivial, so the executables degenerate
        # bit-identically to the mesh=None path.
        self._shardings: Optional[dict] = None
        if mesh is not None:
            from repro.distributed.compressed_pspecs import (
                annotate_reduction_tp,
                check_kv_shard,
                lane_sharding,
                replicated,
                serving_cache_shardings,
                serving_param_shardings,
            )

            check_kv_shard(mesh, kv_shard)
            # stamp reduction-TP'd compressed leaves with their model-axis
            # shard count BEFORE deriving shardings: rshards lives in the
            # pytree aux, so the spec tree must be built from the annotated
            # tree to match leaf-for-leaf under device_put / in_shardings
            params = annotate_reduction_tp(params, mesh, cfg=model.cfg)
            self._shardings = {
                "params": serving_param_shardings(mesh, params, cfg=model.cfg),
                # a mesh-native pool already derived (and applied) the
                # cache sharding tree — reuse it rather than re-walking
                "cache": (
                    self.pool.cache_shardings
                    if self.pool is not None
                    and self.pool.cache_shardings is not None
                    else serving_cache_shardings(
                        mesh, self.cache, self.layout, kv_shard=kv_shard
                    )
                ),
                "lane": lane_sharding(mesh, max_batch),
                "repl": replicated(mesh),
            }
            self.params = jax.device_put(params, self._shardings["params"])
            if self.pool is None:
                self.cache = jax.device_put(self.cache, self._shardings["cache"])

        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.queue: deque[_Request] = deque()
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        if self._shardings is not None:
            self.tokens = jax.device_put(self.tokens, self._shardings["lane"])
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self._admit_seq = 0
        self.decode_steps = 0  # logical token steps (dispatches × K)
        self.dispatches = 0  # jitted decode calls == host syncs
        self.admitted = 0
        self.preemptions = 0
        self.prefix_hits = 0  # admissions that reused cached prefix pages
        self.prefix_hit_tokens = 0  # prompt tokens skipped via the index
        self.max_concurrency = 0
        self.prefill_batches = 0
        self.prefill_chunks = 0  # chunked-prefill dispatches
        self.tokens_generated = 0
        self.decode_tokens = 0  # tokens produced by decode steps (not prefill)
        self.decode_wall_s = 0.0  # dispatch wall time (device + launch)
        self.sched_host_s = 0.0  # host scheduling time around dispatches
        self._util_sum = 0.0
        self._util_n = 0
        self._kv_bytes_sum = 0.0  # live KV bytes summed over decode steps
        self._kv_row_b: Optional[tuple[int, int]] = None  # _kv_row_bytes cache
        # slot-change-triggered host constants (temps/topks/eos/active/keep
        # and the static sampling flags are rebuilt only when the slot set
        # changes, not per dispatch)
        self._slots_dirty = True
        self._consts: Optional[dict] = None

        # recurrent state cannot absorb pad tokens: group by exact length
        plan = layer_plan(model.cfg)
        kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
        self._exact_prefill = any(
            _block_mixer_mlp(k, model.cfg)[0] in ("ssm", "rec") for k in kinds
        )
        # chunked prefill needs every mixer to read mid-prompt state from
        # the cache: attention-family only, and non-windowed (a window that
        # slides during the prompt would need windowed chunk views)
        self._chunk_ok = (
            prefill_chunk is not None
            and not self._exact_prefill
            and model.cfg.local_window is None
        )
        self.prefill_chunk = prefill_chunk if self._chunk_ok else None
        # prefix caching rides the chunked-prefill machinery (a prefix-hit
        # lane is admitted as "already absorbed its first chunks" and the
        # uncached tail drains through _advance_chunks), so it carries the
        # same arch gate — attention-family, non-windowed — plus an
        # append-only full table (windowed pools evict shared pages).
        self._prefix = None
        if prefix_cache:
            lay = self.pool.layout if self.pool is not None else None
            if (
                lay is not None
                and lay.has_full and not lay.win
                and not self._exact_prefill
                and model.cfg.local_window is None
            ):
                from repro.serving.prefix_cache import PrefixIndex

                self._prefix = PrefixIndex(self.pool, lay.page_size)
            else:
                warnings.warn(
                    "prefix_cache=True ignored: needs a paged append-only "
                    "full table on an attention-family, non-windowed arch"
                )
        # tail prefill of a prefix hit reuses the chunk executable even when
        # chunked prefill itself is off — pick a chunk size for that case
        self._tail_chunk = self.prefill_chunk or min(64, max_len)
        if prefill_buckets:
            buckets = sorted(int(b) for b in prefill_buckets if 0 < int(b) <= max_len)
        else:
            buckets, b = [], 8
            while b < max_len:
                buckets.append(b)
                b *= 2
        if not buckets or buckets[-1] < max_len:
            buckets.append(max_len)
        self.prefill_buckets = tuple(buckets)
        self.max_prefill_batch = max_prefill_batch or max_batch

        layout = self.layout
        eng_max_len = max_len

        def _decode(params, tok, cache, temps, topks, active, keep, key,
                    eos, budget, k, need_sample, need_topk):
            # K decode steps fused into one on-device scan: embed → attend →
            # sample → scatter-into-cache → stop-detect, K times, one host
            # sync.  ``active`` lanes decode; ``keep`` lanes (occupied but
            # not decoding, e.g. mid chunked-prefill) hold their length;
            # free lanes pin to 0 so they cannot creep past the cache bound.
            def body(carry, _):
                tok, cache, active, budget, key = carry
                len_prev = cache["len"]
                logits, cache = model.decode_step(params, tok, cache, layout)
                cache["len"] = jnp.where(
                    active, cache["len"], jnp.where(keep, len_prev, 0)
                )
                ks = jax.random.split(key)
                key, sub = ks[0], ks[1]
                nxt = sample_tokens(
                    logits, temps, topks, sub,
                    need_sample=need_sample, need_topk=need_topk,
                )
                nxt, active, budget = advance_stops(
                    nxt, active, budget, eos, cache["len"], eng_max_len
                )
                return (nxt, cache, active, budget, key), nxt

            (tok, cache, active, budget, key), block = jax.lax.scan(
                body, (tok, cache, active, budget, key), None, length=k
            )
            return block, tok, cache, key

        def _prefill(params, tokens, lens, lanes, cache, temps, topks, key,
                     need_sample, need_topk):
            # one jitted call per (bucket_len, group_size): forward the whole
            # padded group, write each row's cache into its lane through the
            # layout, and sample each row's first token at position len-1
            logits_all, _, produced = model.forward(
                params, {"tokens": tokens}, remat=False, want_cache=True
            )
            idx = jnp.clip(lens - 1, 0, tokens.shape[1] - 1)
            logits = jnp.take_along_axis(logits_all, idx[:, None, None], axis=1)[:, 0]
            cache = model.write_prefill(cache, produced, lanes, lens, layout)
            first = sample_tokens(
                logits, temps, topks, key,
                need_sample=need_sample, need_topk=need_topk,
            )
            return first, cache

        def _chunk(params, tokens, cache, lanes, starts, lengths):
            # one dispatch absorbs a chunk of every currently-chunking lane
            return model.prefill_chunk(
                params, tokens, cache, lanes, starts, lengths, layout
            )

        # the need_* flags are static so all-greedy batches compile to a
        # bare argmax (no vocab sort / categorical in the decode hot path);
        # at most 4 _decode variants exist, warmed untimed on first use.
        # donate_argnums hands the cache (and the decode's token buffer) to
        # XLA for in-place update — without it every dispatch copies the
        # whole pool because the engine reuses the input cache.
        jit_kw: dict = {"decode": {}, "prefill": {}, "chunk": {}}
        if self._shardings is not None:
            # pin explicit in/out shardings on every executable: params TP,
            # cache seq/pages-sharded, per-lane vectors over DP, prefill /
            # chunk row batches replicated (they scatter into the sharded
            # cache), rng keys replicated
            from jax.sharding import NamedSharding, PartitionSpec as _P

            psh = self._shardings["params"]
            csh = self._shardings["cache"]
            lane = self._shardings["lane"]
            repl = self._shardings["repl"]
            blk = NamedSharding(mesh, _P(None, *tuple(lane.spec)))
            jit_kw["decode"] = dict(
                in_shardings=(psh, lane, csh, lane, lane, lane, lane, repl,
                              lane, lane),
                out_shardings=(blk, lane, csh, repl),
            )
            jit_kw["prefill"] = dict(
                in_shardings=(psh, repl, repl, repl, csh, repl, repl, repl),
                out_shardings=(repl, csh),
            )
            jit_kw["chunk"] = dict(
                in_shardings=(psh, repl, csh, repl, repl, repl),
                out_shardings=(repl, csh),
            )
        # statics are passed *positionally* (static_argnums): pjit rejects
        # kwargs outright once in_shardings is specified
        self._decode = jax.jit(
            _decode,
            static_argnums=(10, 11, 12),  # k, need_sample, need_topk
            donate_argnums=(1, 2) if donate else (),
            **jit_kw["decode"],
        )
        self._prefill = jax.jit(
            _prefill,
            static_argnums=(8, 9),  # need_sample, need_topk
            donate_argnums=(4,) if donate else (),
            **jit_kw["prefill"],
        )
        self._chunk = jax.jit(
            _chunk, donate_argnums=(2,) if donate else (), **jit_kw["chunk"]
        )
        self._warmed: set[tuple[bool, bool]] = set()

    # -- request intake ------------------------------------------------------

    def submit(
        self, prompt: Sequence[int], sampling: Optional[SamplingParams] = None
    ) -> int:
        """Enqueue a request; returns its uid."""
        prompt = [int(t) for t in prompt]
        sampling = sampling or SamplingParams()
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= cache capacity {self.max_len}"
            )
        if self.pool is not None:
            cap = min(len(prompt) + sampling.max_new_tokens, self.max_len)
            need = self.pool.pages_for_request(cap)
            if need > self.pool.layout.num_pages:
                raise ValueError(
                    f"request needs up to {need} pages but the pool has only "
                    f"{self.pool.layout.num_pages}; raise --num-pages or "
                    "lower max_new_tokens"
                )
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(_Request(uid, prompt, sampling))
        return uid

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _finish(self, i: int, reason: str, out: list[GenerationResult]) -> None:
        s = self.slots[i]
        out.append(GenerationResult(s.uid, s.prompt, s.generated, reason))
        self.tokens_generated += len(s.generated)
        self.slots[i] = None
        self._slots_dirty = True
        if self.pool is not None:
            self.pool.release(i)

    def _absorb(
        self, i: int, token: int, out: list[GenerationResult], *,
        from_decode: bool = False,
    ) -> None:
        """Record a freshly sampled token for slot i; finish on a stop.

        These rules are mirrored on device by ``sampling.advance_stops``
        (the K-step scan's freeze logic) — keep the two in lockstep."""
        s = self.slots[i]
        sp = s.sampling
        if sp.eos_id >= 0 and token == sp.eos_id:
            self._finish(i, "eos", out)
            return
        s.generated.append(token)
        if from_decode:
            self.decode_tokens += 1
        if len(s.generated) >= sp.max_new_tokens:
            self._finish(i, "length", out)
        elif len(s.prompt) + len(s.generated) >= self.max_len:
            # the request hit its logical capacity (page-table width /
            # slab length) — distinct from pool pressure, which preempts
            self._finish(i, "cache_full", out)

    def _preempt(self, i: int, out: list[GenerationResult]) -> None:
        """Evict lane i: free its pages, requeue it with a resume prefix."""
        s = self.slots[i]
        self.slots[i] = None
        self._slots_dirty = True
        self.pool.release(i)
        self.preemptions += 1
        self.queue.appendleft(
            _Request(s.uid, s.prompt, s.sampling, prefix=list(s.generated))
        )

    def _bucket(self, n: int) -> int:
        if self._exact_prefill:
            return n
        for b in self.prefill_buckets:
            if b >= n:
                return b
        return self.prefill_buckets[-1]

    def _admit(self, out: list[GenerationResult]) -> None:
        """Move queued requests into lanes; one batched prefill per bucket.

        Prompts longer than ``prefill_chunk`` take the chunked route: the
        lane is claimed (and its pages reserved) now, but the prompt is
        absorbed chunk-by-chunk across the following scheduling steps.

        With a prefix index, admission first asks it for the longest
        cached prefix (page granularity): the hit pages are mapped shared
        into the lane's table and only the uncached tail is absorbed —
        through the chunked machinery, since a prefix-hit lane is exactly
        a lane that already absorbed its first chunks."""
        picked: list[tuple[_Request, int, int]] = []
        n_taken = 0
        while self.queue and n_taken < self.max_prefill_batch:
            i = self._free_slot()
            if i is None:
                break
            req = self.queue[0]
            seq = list(req.prompt) + list(req.prefix)
            length = len(seq)
            shared_len, shared_pids = 0, ()
            if self._prefix is not None:
                shared_len, shared_pids = self._prefix.match(seq)
            if self.pool is not None:
                ok = self.pool.alloc_prefill(
                    i, length, shared_full=shared_pids, shared_len=shared_len
                )
                # pool pressure: shed LRU index entries before giving up —
                # each evict() can invalidate matched pages, so re-match
                while (
                    not ok
                    and self._prefix is not None
                    and self._prefix.evict(1)
                ):
                    shared_len, shared_pids = self._prefix.match(seq)
                    ok = self.pool.alloc_prefill(
                        i, length, shared_full=shared_pids,
                        shared_len=shared_len,
                    )
                if not ok:
                    break  # retry next step, after frees/preemptions
            self.queue.popleft()
            n_taken += 1
            if shared_len > 0:
                # prefix hit: absorb only the uncached tail, chunk-wise
                self.prefix_hits += 1
                self.prefix_hit_tokens += shared_len
                self.slots[i] = _Slot(
                    req, pos=shared_len, seq=self._admit_seq,
                    pending=seq[shared_len:],
                )
                self._admit_seq += 1
                self.admitted += 1
                self._slots_dirty = True
                continue
            if self.prefill_chunk is not None and length > self.prefill_chunk:
                self.slots[i] = _Slot(
                    req, pos=0, seq=self._admit_seq,
                    pending=seq,
                )
                self._admit_seq += 1
                self.admitted += 1
                self._slots_dirty = True
                continue
            self.slots[i] = _Slot(req, pos=length, seq=self._admit_seq)
            self._admit_seq += 1
            self._slots_dirty = True
            picked.append((req, i, length))
        if not picked:
            return
        groups: dict[int, list[tuple[_Request, int, int]]] = {}
        for item in picked:
            groups.setdefault(self._bucket(item[2]), []).append(item)
        for lb in sorted(groups):
            self._prefill_group(lb, groups[lb], out)

    def _prefill_group(
        self, lb: int, items: list[tuple[_Request, int, int]],
        out: list[GenerationResult],
    ) -> None:
        nb = _next_pow2(len(items))
        tokens = np.zeros((nb, lb), np.int32)
        lens = np.zeros((nb,), np.int32)
        lanes = np.full((nb,), self.max_batch, np.int32)  # sentinel = pad row
        temps = np.zeros((nb,), np.float32)
        topks = np.zeros((nb,), np.int32)
        for r, (req, i, length) in enumerate(items):
            tokens[r, :length] = req.prompt + req.prefix
            lens[r] = length
            lanes[r] = i
            temps[r] = req.sampling.temperature
            topks[r] = req.sampling.top_k
        need_sample = any(req.sampling.temperature > 0 for req, _, _ in items)
        need_topk = any(req.sampling.top_k > 0 for req, _, _ in items)
        self.key, sub = jax.random.split(self.key)
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        with self._kernel_ctx(), _quiet_donation():
            first, self.cache = self._prefill(
                self.params, jnp.asarray(tokens), jnp.asarray(lens),
                jnp.asarray(lanes), self.cache, jnp.asarray(temps),
                jnp.asarray(topks), sub, need_sample, need_topk,
            )
        if self.pool is not None:
            # the donated call consumed the table buffers the pool held;
            # re-anchor its incremental sync on the returned arrays
            self.pool.adopt_tables(self.cache.get("tables"))
        self.tokens = self.tokens.at[lanes].set(first, mode="drop")
        self.prefill_batches += 1
        host_first = np.asarray(first)
        if self._prefix is not None:
            # index the freshly written pages while the lane still maps
            # them (_absorb may finish the lane and release its claim;
            # the index's own references keep the KV resident)
            for req, i, length in items:
                full, tail = self.pool.prompt_pages(i, length)
                self._prefix.insert(
                    req.prompt + req.prefix, full, tail,
                    length % self.pool.layout.page_size,
                )
        for r, (req, i, _) in enumerate(items):
            self.admitted += 1
            self._absorb(i, int(host_first[r]), out)

    def _advance_chunks(self, out: list[GenerationResult]) -> None:
        """One prompt chunk of *every* chunk-prefilling lane per scheduling
        step, absorbed by a single batched dispatch (rows padded to a power
        of two with sentinel lanes, so the executable retraces O(log B)
        times, not per lane count).  Previously each chunking lane cost its
        own dispatch per step.

        A lane's final chunk's logits seed its request's first sampled
        token, so a lane never idles fully-prefilled-but-unsampled across a
        dispatch.
        """
        # prefix-hit lanes drain their uncached tail here even when chunked
        # prefill proper is off — _tail_chunk covers that case
        csz = self.prefill_chunk or self._tail_chunk
        chunking = [
            i for i, s in enumerate(self.slots) if s is not None and s.pending
        ]
        if not chunking:
            return
        nb = _next_pow2(len(chunking))
        toks = np.zeros((nb, csz), np.int32)
        lanes = np.full((nb,), self.max_batch, np.int32)  # sentinel = pad row
        starts = np.zeros((nb,), np.int32)
        lengths = np.zeros((nb,), np.int32)
        for r, i in enumerate(chunking):
            s = self.slots[i]
            part = s.pending[:csz]
            toks[r, : len(part)] = part
            lanes[r] = i
            starts[r] = s.pos
            lengths[r] = len(part)
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        with self._kernel_ctx(), _quiet_donation():
            logits, self.cache = self._chunk(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(lanes), jnp.asarray(starts), jnp.asarray(lengths),
            )
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        self.prefill_chunks += 1
        finishing: list[tuple[int, int]] = []  # (row, lane)
        for r, i in enumerate(chunking):
            s = self.slots[i]
            took = int(lengths[r])
            s.pos += took
            s.pending = s.pending[took:]
            if not s.pending:
                finishing.append((r, i))
        if finishing and self._prefix is not None:
            # the lane's whole prompt(+resume prefix) is now cached: index
            # its pages before _absorb can finish/release the lane
            for _, i in finishing:
                s = self.slots[i]
                full, tail = self.pool.prompt_pages(i, s.pos)
                self._prefix.insert(
                    s.prompt + s.generated, full, tail,
                    s.pos % self.pool.layout.page_size,
                )
        if finishing:
            temps = np.zeros((nb,), np.float32)
            topks = np.zeros((nb,), np.int32)
            for r, i in finishing:
                sp = self.slots[i].sampling
                temps[r] = sp.temperature
                topks[r] = sp.top_k
            self.key, sub = jax.random.split(self.key)
            first = sample_tokens(
                logits, jnp.asarray(temps), jnp.asarray(topks), sub,
                need_sample=bool((temps > 0).any()),
                need_topk=bool((topks > 0).any()),
            )
            host_first = np.asarray(first)
            for r, i in finishing:
                self.tokens = self.tokens.at[i].set(first[r])
                self._slots_dirty = True
                self._absorb(i, int(host_first[r]), out)

    def _ensure_capacity(self, out: list[GenerationResult]) -> None:
        """Back every decoding lane's next K writes; preempt on pressure.

        Lanes are served oldest-first and victims chosen youngest-first, so
        the oldest request always makes progress (a request that could
        never fit alone is rejected at submit).  Reserving the whole
        dispatch up front (``ensure_steps``) is what rules out mid-scan
        pool exhaustion."""
        if self.pool is None:
            return
        order = sorted(
            (
                i for i, s in enumerate(self.slots)
                if s is not None and not s.pending
            ),
            key=lambda i: self.slots[i].seq,
        )
        for i in order:
            s = self.slots[i]
            if s is None:  # already evicted as an earlier lane's victim
                continue
            # a lane whose remaining token budget is < K freezes on device
            # before the scan ends — don't reserve (and potentially preempt
            # someone for) pages its writes will never reach
            k = max(
                1,
                min(
                    self.steps_per_dispatch,
                    s.sampling.max_new_tokens - len(s.generated),
                ),
            )
            while self.slots[i] is not None and not self.pool.ensure_steps(
                i, self.slots[i].pos, k
            ):
                # cached-but-idle prefix pages are cheaper to give up than
                # a live lane: shed LRU index entries before preempting
                if self._prefix is not None and self._prefix.evict(1):
                    continue
                victim = max(
                    (j for j, t in enumerate(self.slots) if t is not None),
                    key=lambda j: self.slots[j].seq,
                )
                self._preempt(victim, out)
                if victim == i:
                    break

    def _slot_consts(self) -> dict:
        """Per-lane device constants, rebuilt only when the slot set changes
        (not per dispatch — the per-step rebuild was pure host overhead)."""
        if not self._slots_dirty and self._consts is not None:
            return self._consts
        decode = [s is not None and not s.pending for s in self.slots]
        keep = [s is not None for s in self.slots]
        self._consts = {
            "active_np": np.array(decode),
            "active": jnp.asarray(np.array(decode)),
            "keep": jnp.asarray(np.array(keep)),
            "temps": jnp.asarray(
                [
                    s.sampling.temperature if (s and not s.pending) else 0.0
                    for s in self.slots
                ],
                jnp.float32,
            ),
            "topks": jnp.asarray(
                [
                    s.sampling.top_k if (s and not s.pending) else 0
                    for s in self.slots
                ],
                jnp.int32,
            ),
            "eos": jnp.asarray(
                [
                    s.sampling.eos_id if (s and not s.pending) else -1
                    for s in self.slots
                ],
                jnp.int32,
            ),
            "need_sample": any(
                s is not None and not s.pending and s.sampling.temperature > 0
                for s in self.slots
            ),
            "need_topk": any(
                s is not None and not s.pending and s.sampling.top_k > 0
                for s in self.slots
            ),
        }
        self._slots_dirty = False
        return self._consts

    def step(self) -> list[GenerationResult]:
        """One scheduling step: admit what fits, advance chunked prefills,
        run one fused K-step decode dispatch; return finished requests."""
        out: list[GenerationResult] = []
        self._admit(out)
        if self.prefill_chunk is not None or self._prefix is not None:
            self._advance_chunks(out)
        t_prefill_done = time.perf_counter()
        self._ensure_capacity(out)
        consts = self._slot_consts()
        active = consts["active_np"]
        self.max_concurrency = max(self.max_concurrency, int(active.sum()))
        if not active.any():
            return out
        self._util_sum += self._cache_utilization()
        self._util_n += 1
        self._kv_bytes_sum += self._live_kv_bytes()
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        k = self.steps_per_dispatch
        budget = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and not s.pending:
                budget[i] = s.sampling.max_new_tokens - len(s.generated)
        args = (
            self.params, self.tokens, self.cache, consts["temps"],
            consts["topks"], consts["active"], consts["keep"], self.key,
            consts["eos"], jnp.asarray(budget),
        )
        sig = (k, consts["need_sample"], consts["need_topk"])
        t_sched = time.perf_counter()  # warmup compile time is not host overhead
        if sig not in self._warmed:
            # untimed warmup: trace+compile of this variant must not land in
            # decode_wall_s (it would dominate ms_per_decode_step on short
            # runs).  The warmup runs on *copies* of the donated operands so
            # the originals stay valid for the timed call, whose result is
            # the one absorbed.
            wargs = args
            if self.donate:
                tok_c, cache_c = jax.tree_util.tree_map(
                    jnp.copy, (args[1], args[2])
                )
                wargs = (args[0], tok_c, cache_c) + args[3:]
            with self._kernel_ctx(), _quiet_donation():
                jax.block_until_ready(self._decode(*wargs, *sig))
            self._warmed.add(sig)
        t0 = time.perf_counter()
        with self._kernel_ctx(), _quiet_donation():
            block, tok, self.cache, self.key = self._decode(*args, *sig)
            tok.block_until_ready()
        t1 = time.perf_counter()
        self.decode_wall_s += t1 - t0
        self.decode_steps += k
        self.dispatches += 1
        self.tokens = tok
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        host_block = np.asarray(block)  # (K, B): one sync per K tokens
        live = [i for i in range(self.max_batch) if active[i]]
        for t in range(k):
            for i in list(live):
                self.slots[i].pos += 1  # mirror cache["len"] advancing
            for i in list(live):
                self._absorb(i, int(host_block[t, i]), out, from_decode=True)
                if self.slots[i] is None:
                    live.remove(i)
        t_end = time.perf_counter()
        self.sched_host_s += (t_sched - t_prefill_done) + (t_end - t1)
        return out

    def run(self) -> dict[int, GenerationResult]:
        """Drain the queue and all active slots; results keyed by uid."""
        results: dict[int, GenerationResult] = {}
        while self.queue or any(s is not None for s in self.slots):
            for r in self.step():
                results[r.uid] = r
        return results

    # -- reporting -----------------------------------------------------------

    def _cache_utilization(self) -> float:
        """Fraction of *reserved* cache token-slots holding live tokens.

        The slab reserves ``max_batch × max_len`` slots unconditionally;
        the paged pool reserves only its allocated pages — this ratio is
        what block-granular allocation buys on heterogeneous traffic.
        """
        lane_lens = {i: s.pos for i, s in enumerate(self.slots) if s is not None}
        if self.pool is not None:
            denom = self.pool.used_pages * self.pool.layout.page_size
            live = self.pool.live_tokens(lane_lens)
        else:
            denom = self.max_batch * self.max_len
            live = sum(min(p, self.max_len) for p in lane_lens.values())
        return live / denom if denom else 0.0

    def weight_bytes_per_step(self) -> int:
        """HBM weight bytes one decode step must read: every parameter leaf
        once, ``CompressedTensor`` leaves at their *stored* (compressed)
        size — the numerator of the N:M bandwidth win.  MoE archs overcount
        by the unrouted experts (all experts are resident; a step reads
        only top-k), so treat this as the dense-roofline bound.
        """
        total = 0
        for leaf in jax.tree_util.tree_leaves(
            self.params, is_leaf=lambda x: isinstance(x, CompressedTensor)
        ):
            total += int(leaf.nbytes)
        return total

    def _kv_row_bytes(self) -> tuple[int, int]:
        """(append-only, windowed) cache bytes per token per lane, summed
        over layers.  Constant for the engine's lifetime — computed once
        (step() calls this per decode step)."""
        if self._kv_row_b is not None:
            return self._kv_row_b
        cfg = self.model.cfg
        itemsize = jnp.dtype(cfg.param_dtype).itemsize
        plan = layer_plan(cfg)
        kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
        full_b = win_b = 0
        windowed = (
            cfg.local_window is not None and cfg.local_window <= self.max_len
        )
        for kind in kinds:
            mixer = _block_mixer_mlp(kind, cfg)[0]
            if mixer == "attn":
                rb = 2 * cfg.n_kv * cfg.hd * itemsize
                if windowed:
                    win_b += rb
                else:
                    full_b += rb
            elif mixer == "mla":
                full_b += (cfg.mla.kv_lora + cfg.mla.rope_head_dim) * itemsize
        self._kv_row_b = (full_b, win_b)
        return self._kv_row_b

    def _live_kv_bytes(self) -> int:
        """KV bytes the *paged fast path* reads this step: each active
        lane's live tokens once.  (The gathered reference reads — and
        rewrites — the full ``B × S_max`` view instead; the slab engine
        has no choice.  This is the bytes-read-per-step roofline input
        that ``benchmarks/serve_bench.py`` records.)"""
        full_b, win_b = self._kv_row_bytes()
        win = (
            min(self.max_len, self.model.cfg.local_window)
            if self.model.cfg.local_window is not None
            else self.max_len
        )
        total = 0
        for s in self.slots:
            if s is not None:
                total += full_b * min(s.pos + 1, self.max_len)
                total += win_b * min(s.pos + 1, win)
        return total

    def kv_cache_bytes(self) -> int:
        """Device bytes held by attention/MLA cache storage (slab or pool)."""
        plan = layer_plan(self.model.cfg)
        total = 0

        def entry_bytes(entry) -> int:
            return sum(x.nbytes for x in jax.tree_util.tree_leaves(entry))

        for i, kind in enumerate(plan.head):
            if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                total += entry_bytes(self.cache[f"head_{i}"])
        if plan.n_body:
            for j, kind in enumerate(plan.period):
                if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                    total += entry_bytes(self.cache["body"][f"sb_{j}"])
        for i, kind in enumerate(plan.tail):
            if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                total += entry_bytes(self.cache[f"tail_{i}"])
        return total

    def _kernel_ctx(self):
        """Dispatch mesh context for executable calls.  ``jax.jit``
        (re)traces lazily per signature, so the context must wrap *every*
        call, not just the first: any trace happening inside may route
        ``shards > 1`` kernel calls to the shard_map wrappers
        (``kernels.dispatch.mesh_context``).  A mesh-less engine gets a
        no-op context."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.kernels import dispatch

        return dispatch.mesh_context(self.mesh)

    def kernel_route(self) -> str:
        """The paged-attention route decode resolves at trace time —
        ``"shard_map"`` / ``"xla"`` / ``"pallas"`` / ``"interpret"`` for
        paged engines, ``"slab"`` when no paged kernel is in play.
        Mirrors the in-trace resolution (same mesh context + shape info)
        so benches can record which implementation a measured stream ran
        on without re-lowering the executable."""
        if self.pool is None:
            return "slab"
        from repro.kernels import dispatch

        lay = self.layout
        n_slots = lay.pages_full if lay.pages_full else lay.pages_win
        with self._kernel_ctx():
            mode, _ = dispatch.resolve(
                "paged_attn", b=self.max_batch, n_slots=n_slots,
                page_size=lay.page_size, num_pages=lay.num_pages,
                shards=lay.shards,
            )
        return mode

    def mesh_desc(self) -> Optional[dict]:
        """{"shape": [...], "axes": [...]} for the engine's mesh (None =
        single-device) — the schema serve_bench records under ``mesh``."""
        if self.mesh is None:
            return None
        return {
            "shape": [int(s) for s in self.mesh.devices.shape],
            "axes": [str(a) for a in self.mesh.axis_names],
        }

    def sharding_report(self, include_hlo: bool = False) -> dict:
        """Per-shard placement facts for the mesh-native engine.

        Reports, per weight/cache leaf and in aggregate, the bytes one
        shard holds (``sharding.shard_shape``) next to the global bytes —
        the per-shard HBM numbers the serve_bench sharded sweep records —
        plus which weight leaves ended up fully replicated (none should,
        for 2-D+ matmul weights on a model-axis mesh).  With
        ``include_hlo=True`` the decode executable is lowered + compiled
        for the engine's current shapes and its collective mix
        (all-reduce/all-gather/... counts and bytes) and per-argument input
        shardings are extracted — the "live executable" view the sharded
        serving tests assert on.
        """
        import math

        def shard_bytes(x) -> int:
            if self.mesh is not None and hasattr(x, "sharding"):
                return (
                    math.prod(x.sharding.shard_shape(x.shape))
                    * x.dtype.itemsize
                )
            return int(x.size * x.dtype.itemsize)

        from repro.utils.tree import _path_str

        weights = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.params):
            weights[_path_str(path)] = {
                "bytes": int(leaf.size * leaf.dtype.itemsize),
                "bytes_per_shard": shard_bytes(leaf),
                "ndim": int(leaf.ndim),
                "replicated": (
                    bool(leaf.sharding.is_fully_replicated)
                    if hasattr(leaf, "sharding") else True
                ),
            }

        def is_matmul_leaf(name: str, w: dict) -> bool:
            # per-feature vectors (norm scales, biases — stacked ones are
            # 2-D) replicate by design; counting them would bury a real
            # weight-replication regression in constant noise
            return w["ndim"] >= 2 and not any(
                f in name for f in ("bias", "norm", "scale")
            )
        cache_total = cache_shard = 0
        for leaf in jax.tree_util.tree_leaves(self.cache):
            cache_total += int(leaf.size * leaf.dtype.itemsize)
            cache_shard += shard_bytes(leaf)
        report = {
            "mesh": self.mesh_desc(),
            "weights": weights,
            "weight_bytes": sum(w["bytes"] for w in weights.values()),
            "weight_bytes_per_shard": sum(
                w["bytes_per_shard"] for w in weights.values()
            ),
            # the regression signal: matmul weights (ndim >= 2, not a
            # per-feature vector) that ended up fully replicated — 0 on a
            # healthy model-axis mesh
            "replicated_matmul_leaves": sum(
                1 for name, w in weights.items()
                if w["replicated"] and is_matmul_leaf(name, w)
            ),
            "cache_bytes": cache_total,
            "cache_bytes_per_shard": cache_shard,
        }
        if include_hlo:
            from repro.utils import hlo_cost as HC

            consts = self._slot_consts()
            budget = jnp.zeros((self.max_batch,), jnp.int32)
            with self._kernel_ctx():
                lowered = self._decode.lower(
                    self.params, self.tokens, self.cache, consts["temps"],
                    consts["topks"], consts["active"], consts["keep"],
                    self.key, consts["eos"], budget,
                    self.steps_per_dispatch, False, False,
                )
            compiled = lowered.compile()
            walk = HC.analyze(compiled.as_text())
            report["decode_collective_bytes"] = walk["collective_bytes"]
            report["decode_collective_total"] = walk["collective_total"]
            n_weight_leaves = len(jax.tree_util.tree_leaves(self.params))
            try:
                flat_in = jax.tree_util.tree_leaves(compiled.input_shardings[0])
                report["decode_weight_inputs_replicated"] = [
                    bool(s.is_fully_replicated)
                    for s in flat_in[:n_weight_leaves]
                ]
            except Exception:  # AOT introspection API drift: report omits it
                report["decode_weight_inputs_replicated"] = None
        return report

    def stats(self) -> dict:
        # throughput counts only decode-produced tokens over decode wall time;
        # each request's first token comes from (untimed) prefill and would
        # otherwise inflate tokens/s
        wb = self.weight_bytes_per_step()
        kvb = (
            self._kv_bytes_sum / self.dispatches if self.dispatches else 0.0
        )
        total_wall = self.decode_wall_s + self.sched_host_s
        st = {
            "layout": self.layout.kind,
            "decode_steps": self.decode_steps,
            "dispatches": self.dispatches,
            "steps_per_dispatch": self.steps_per_dispatch,
            "host_syncs": self.dispatches,
            "donate": self.donate,
            "admitted": self.admitted,
            "preemptions": self.preemptions,
            "max_concurrency": self.max_concurrency,
            "prefill_batches": self.prefill_batches,
            "prefill_chunks": self.prefill_chunks,
            "tokens_generated": self.tokens_generated,
            "decode_tokens": self.decode_tokens,
            "decode_wall_s": self.decode_wall_s,
            "sched_host_s": self.sched_host_s,
            "kv_cache_bytes": self.kv_cache_bytes(),
            "hbm_cache_utilization": (
                self._util_sum / self._util_n if self._util_n else 0.0
            ),
            # per *logical token step*: device-side dispatch wall vs the
            # host-scheduling overhead amortized over the K tokens it buys
            "ms_per_decode_step": (
                self.decode_wall_s / self.decode_steps * 1e3
                if self.decode_steps
                else 0.0
            ),
            "ms_per_decode_step_host": (
                self.sched_host_s / self.decode_steps * 1e3
                if self.decode_steps
                else 0.0
            ),
            "host_overhead_frac": (
                self.sched_host_s / total_wall if total_wall > 0 else 0.0
            ),
            # decode-step roofline inputs: weight stream + mean live-KV read
            "weight_bytes_per_step": wb,
            "kv_bytes_per_step": kvb,
            "bytes_read_per_step": wb + kvb,
            "tokens_per_s": (
                self.decode_tokens / self.decode_wall_s
                if self.decode_wall_s > 0
                else 0.0
            ),
        }
        if self.pool is not None:
            lane_lens = {
                i: s.pos for i, s in enumerate(self.slots) if s is not None
            }
            used = self.pool.used_pages
            st["num_pages"] = self.pool.layout.num_pages
            st["page_size"] = self.pool.layout.page_size
            st["used_pages"] = used
            st["evicted_pages"] = self.pool.evicted_pages
            st["page_utilization"] = used / max(1, self.pool.layout.num_pages)
            live = self.pool.live_tokens(lane_lens)
            st["token_utilization"] = (
                live / (used * self.pool.layout.page_size) if used else 0.0
            )
            st["table_full_uploads"] = self.pool.table_full_uploads
            st["table_row_syncs"] = self.pool.table_row_syncs
            st["table_syncs"] = self.pool.table_syncs
            st["kv_quant"] = self.pool.layout.quant
            st["shared_pages"] = self.pool.shared_pages
            st["cow_copies"] = self.pool.cow_copies
        if self._prefix is not None:
            st["prefix_cache"] = True
            st["prefix_indexed_pages"] = self._prefix.pages
            st["prefix_evictions"] = self._prefix.evictions
            st["prefix_hits"] = self.prefix_hits
            st["prefix_hit_tokens"] = self.prefix_hit_tokens
            st["prefix_hit_rate"] = (
                self.prefix_hits / self.admitted if self.admitted else 0.0
            )
        return st
