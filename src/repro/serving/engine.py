"""Continuous-batching decode engine over a (compressed or dense) model tree.

The engine is the serving counterpart of ``launch/train.py``'s Trainer: it
owns a preallocated KV-cache pool of ``max_batch`` slots, a FIFO request
queue, and the jitted prefill/decode executables, and it serves the
parameter tree it is given *as is*. Hand it the N:M-compressed artifact
from ``sparse_infer.compress_params`` and every weight matmul inside
``model.prefill`` / ``model.decode_step`` routes through the compressed
``nm_spmm`` path (see ``models.layers.matmul``) — the dense weights never
materialize in HBM.

Scheduling is continuous batching: whenever a slot frees up (a request hit
its stop condition) the next queued request is admitted *between decode
steps* — one prefill writes its cache into the free slot and the following
decode step carries the new request alongside the in-flight ones. Per-slot
``cache["len"]`` keeps heterogeneous sequence positions correct (including
per-lane rolling-window shifts on sliding-window archs); idle slots are
pinned to length 0 and their sampled tokens discarded.

Prefill retraces per distinct prompt length (shapes are static under jit);
serve traffic with a small set of prompt lengths, or pad client-side.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import TransformerLM
from repro.serving.sampling import SamplingParams, sample_tokens


@dataclasses.dataclass
class GenerationResult:
    """A completed request."""

    uid: int
    prompt: list[int]
    tokens: list[int]  # generated tokens (eos not included)
    finish_reason: str  # "eos" | "length" | "cache_full"


@dataclasses.dataclass
class _Request:
    uid: int
    prompt: list[int]
    sampling: SamplingParams


class _Slot:
    """Host-side bookkeeping for one active batch lane."""

    __slots__ = ("uid", "prompt", "sampling", "generated")

    def __init__(self, req: _Request):
        self.uid = req.uid
        self.prompt = req.prompt
        self.sampling = req.sampling
        self.generated: list[int] = []


class DecodeEngine:
    """Batched decode over a fixed-size slot pool with continuous batching.

    Parameters
    ----------
    model: the ``TransformerLM`` wrapper (provides prefill/decode_step).
    params: the serving tree — dense arrays and/or ``CompressedTensor``
        leaves; served directly, no rehydration.
    max_batch: number of concurrent decode lanes (cache pool size).
    max_len: per-slot cache capacity (prompt + generated tokens).
    """

    def __init__(
        self,
        model: TransformerLM,
        params: Any,
        *,
        max_batch: int = 8,
        max_len: int = 128,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = model.init_cache(max_batch, max_len)
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.queue: deque[_Request] = deque()
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self.decode_steps = 0
        self.admitted = 0
        self.tokens_generated = 0
        self.decode_tokens = 0  # tokens produced by decode steps (not prefill)
        self.decode_wall_s = 0.0

        def _decode(params, tok, cache, temps, topks, active, key,
                    need_sample, need_topk):
            logits, cache = model.decode_step(params, tok, cache)
            # idle lanes: pin position so a freed slot cannot creep past the
            # cache bound while it waits for its next request
            cache["len"] = jnp.where(active, cache["len"], 0)
            nxt = sample_tokens(
                logits, temps, topks, key,
                need_sample=need_sample, need_topk=need_topk,
            )
            return jnp.where(active, nxt, 0), logits, cache

        def _insert(params, pool, prompt, slot, temp, topk, key,
                    need_sample, need_topk):
            # single-request prefill, written into the pool at `slot`
            # (model.write_cache_slot owns the pool's axis layout)
            logits, c1 = model.prefill(
                params, {"tokens": prompt[None, :]}, max_len=max_len
            )
            pool = model.write_cache_slot(pool, c1, slot)
            first = sample_tokens(
                logits, temp[None], topk[None], key,
                need_sample=need_sample, need_topk=need_topk,
            )
            return first[0], pool

        # the need_* flags are static so all-greedy batches compile to a
        # bare argmax (no vocab sort / categorical in the decode hot path);
        # at most 4 _decode variants exist, warmed untimed on first use
        self._decode = jax.jit(
            _decode, static_argnames=("need_sample", "need_topk")
        )
        self._insert = jax.jit(
            _insert, static_argnames=("need_sample", "need_topk")
        )
        self._warmed: set[tuple[bool, bool]] = set()

    # -- request intake ------------------------------------------------------

    def submit(
        self, prompt: Sequence[int], sampling: Optional[SamplingParams] = None
    ) -> int:
        """Enqueue a request; returns its uid."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= cache capacity {self.max_len}"
            )
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(_Request(uid, prompt, sampling or SamplingParams()))
        return uid

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _finish(self, i: int, reason: str, out: list[GenerationResult]) -> None:
        s = self.slots[i]
        out.append(GenerationResult(s.uid, s.prompt, s.generated, reason))
        self.tokens_generated += len(s.generated)
        self.slots[i] = None

    def _absorb(
        self, i: int, token: int, out: list[GenerationResult], *,
        from_decode: bool = False,
    ) -> None:
        """Record a freshly sampled token for slot i; finish on a stop."""
        s = self.slots[i]
        sp = s.sampling
        if sp.eos_id >= 0 and token == sp.eos_id:
            self._finish(i, "eos", out)
            return
        s.generated.append(token)
        if from_decode:
            self.decode_tokens += 1
        if len(s.generated) >= sp.max_new_tokens:
            self._finish(i, "length", out)
        elif len(s.prompt) + len(s.generated) >= self.max_len:
            # the cache has no room to ingest this token — stop here
            self._finish(i, "cache_full", out)

    def _admit(self, req: _Request, i: int, out: list[GenerationResult]) -> None:
        self.key, sub = jax.random.split(self.key)
        first, self.cache = self._insert(
            self.params,
            self.cache,
            jnp.asarray(req.prompt, jnp.int32),
            i,
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k),
            sub,
            need_sample=req.sampling.temperature > 0,
            need_topk=req.sampling.top_k > 0,
        )
        self.tokens = self.tokens.at[i].set(first)
        self.slots[i] = _Slot(req)
        self.admitted += 1
        self._absorb(i, int(first), out)

    def step(self) -> list[GenerationResult]:
        """Admit what fits, run one decode step; return finished requests."""
        out: list[GenerationResult] = []
        while self.queue:
            i = self._free_slot()
            if i is None:
                break
            self._admit(self.queue.popleft(), i, out)
        active = np.array([s is not None for s in self.slots])
        if not active.any():
            return out
        self.key, sub = jax.random.split(self.key)
        temps = jnp.asarray(
            [s.sampling.temperature if s else 0.0 for s in self.slots], jnp.float32
        )
        topks = jnp.asarray(
            [s.sampling.top_k if s else 0 for s in self.slots], jnp.int32
        )
        flags = dict(
            need_sample=any(
                s is not None and s.sampling.temperature > 0 for s in self.slots
            ),
            need_topk=any(
                s is not None and s.sampling.top_k > 0 for s in self.slots
            ),
        )
        args = (
            self.params, self.tokens, self.cache, temps, topks,
            jnp.asarray(active), sub,
        )
        sig = (flags["need_sample"], flags["need_topk"])
        if sig not in self._warmed:
            # untimed warmup: trace+compile of this variant must not land in
            # decode_wall_s (it would dominate ms_per_decode_step on short
            # runs); the result is discarded and the timed call recomputes
            jax.block_until_ready(self._decode(*args, **flags))
            self._warmed.add(sig)
        t0 = time.perf_counter()
        tok, _, self.cache = self._decode(*args, **flags)
        tok.block_until_ready()
        self.decode_wall_s += time.perf_counter() - t0
        self.decode_steps += 1
        self.tokens = tok
        host_tok = np.asarray(tok)
        for i in range(self.max_batch):
            if self.slots[i] is not None:
                self._absorb(i, int(host_tok[i]), out, from_decode=True)
        return out

    def run(self) -> dict[int, GenerationResult]:
        """Drain the queue and all active slots; results keyed by uid."""
        results: dict[int, GenerationResult] = {}
        while self.queue or any(s is not None for s in self.slots):
            for r in self.step():
                results[r.uid] = r
        return results

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        # throughput counts only decode-produced tokens over decode wall time;
        # each request's first token comes from (untimed) prefill and would
        # otherwise inflate tokens/s
        return {
            "decode_steps": self.decode_steps,
            "admitted": self.admitted,
            "tokens_generated": self.tokens_generated,
            "decode_tokens": self.decode_tokens,
            "decode_wall_s": self.decode_wall_s,
            "ms_per_decode_step": (
                self.decode_wall_s / self.decode_steps * 1e3
                if self.decode_steps
                else 0.0
            ),
            "tokens_per_s": (
                self.decode_tokens / self.decode_wall_s
                if self.decode_wall_s > 0
                else 0.0
            ),
        }
