"""Continuous-batching decode engine over a (compressed or dense) model tree.

The engine is the serving counterpart of ``launch/train.py``'s Trainer: it
owns the KV-cache (a per-lane slab, or a block-granular paged pool — see
below), a FIFO request queue, and the jitted prefill/decode executables,
and it serves the parameter tree it is given *as is*.  Hand it the
N:M-compressed artifact from ``sparse_infer.compress_params`` and every
weight matmul inside prefill / decode routes through the compressed
``nm_spmm`` path (see ``models.layers.matmul``) — the dense weights never
materialize in HBM.

Scheduling: dispatch-boundary continuous batching
-------------------------------------------------
The decode hot loop is **fused and zero-copy**: one jitted dispatch runs
``steps_per_dispatch`` (K) decode steps as an on-device ``lax.scan`` that
embeds, attends, samples, scatters each new token into the cache, and
advances ``cache["len"]`` — K tokens per lane move device→host as a single
``(K, max_batch)`` block, so the host is consulted once per K tokens
instead of once per token.  The cache pytree and token buffer are
**donated** (``donate_argnums``) into the decode, prefill, and
chunked-prefill executables, so XLA updates the paged pool in place
instead of copying every cache buffer per call; pass ``donate=False`` for
the copying baseline (bit-identical streams, strictly more HBM traffic).

All scheduling happens at **dispatch boundaries**: queued requests are
admitted (batched bucketed prefill), finished lanes retire, and — under
pool pressure — preemption victims are chosen, only between dispatches.
Mid-scan, per-lane stop detection runs **on device**
(``sampling.advance_stops``): a lane that emits its EOS, exhausts its
``max_new_tokens`` budget, or hits the logical capacity freezes (stops
sampling, stops writing, stops advancing its length) until the host
replays the same rules over the token block at the boundary.  The paged
pool pre-reserves every page the K writes need (``ensure_steps``) before
the dispatch, so mid-scan pool exhaustion cannot occur.  Per-slot
``cache["len"]`` keeps heterogeneous sequence positions correct; idle
lanes are pinned to length 0 and their sampled tokens discarded.

**Chunked prefill** (``prefill_chunk=N``): a prompt longer than N tokens
no longer head-of-line-blocks in-flight decodes behind one monolithic
prefill — it is absorbed N tokens at a time, one chunk per scheduling
step, interleaved with the decode dispatches of the running lanes; the
final chunk samples the request's first token and the lane joins the next
decode dispatch.  Attention-family archs only (recurrent state cannot
resume mid-prompt).  Sliding-window archs chunk on the *paged* layout:
each chunk reads a windowed ring view of the cache
(``cache.PagedLayout.attn_chunk_view_win``) and maps its window-ring
pages chunk-by-chunk (``alloc_prefill(defer_win=True)`` at admission,
``ensure_steps`` per chunk), so a window that slides during the prompt
stays collision-free as long as the pool's ``lookahead >= chunk``.
Slab windowed prompts keep whole-prompt prefill.

Device-resident scheduling (run-until-stop, refill, async streams)
------------------------------------------------------------------
``max_steps_per_dispatch=K`` swaps the fixed-K ``lax.scan`` for an
on-device ``lax.while_loop``: the loop decodes until **some lane
freezes** (``sampling.advance_stops`` decides continuation on device) or
the K-step bound, so short answers stop syncing the host every K tokens
and long answers amortize one host sync over up to K·B tokens.  Sampling
keys are a pure function of ``(request uid, generated-token index)``
(``sampling.request_keys``), so streams — greedy *and* sampled — are
bit-identical to the fixed-K sync scheduler no matter how dispatches are
cut.

``staged_lanes=Q`` pre-stages up to Q queued prompts on device: their
token buffers and pre-reserved page-table rows
(``kv_pool.PagedKVPool.stage_alloc``) ride along in the scheduler state,
and when a lane freezes mid-loop the while-loop swaps a staged request
into the dead lane — table rows installed, recurrent state zeroed
(``model.reset_lanes``), prompt fed token-by-token from the staged
buffer — and starts its prefill **inside the same dispatch**.  The host
finds out at the next sync (``consumed_lane``/``consumed_step``) and
replays the swap in its bookkeeping.

``async_stream=True`` double-buffers dispatches: two while-loop calls
are enqueued back-to-back (the scheduler state and cache chain device
side), so dispatch N+1 executes while the host fetches and replays
dispatch N's token block — decode never waits on a host read.  All host
mutations (admission, staging, page reservation, table sync) happen only
at full-drain cycle boundaries, which is what keeps the
never-write-unmapped invariant without mid-flight synchronization; the
host-side stop replay is unchanged, so streams stay bit-identical.

Cache layouts
-------------
``DecodeEngine`` runs over either cache layout behind the
``models.cache.CacheLayout`` seam:

- **slab** (default): one contiguous ``(max_batch, max_len, ...)`` slab per
  attention/MLA layer.  Admission = a free lane; a request that outgrows
  ``max_len`` finishes with ``finish_reason="cache_full"``.
- **paged** (pass ``num_pages``/``page_size`` or a prebuilt
  ``kv_pool.PagedKVPool``): each layer owns a ``(num_pages, page_size, ...)``
  pool and per-lane *page tables* map logical token positions to physical
  pages.  Admission requires a free lane *and* enough free pages for the
  prompt; page tables grow on demand as lanes decode, and the device copy
  is synced **incrementally** — only lanes whose rows changed since the
  last dispatch are scattered into the resident table arrays
  (``PagedKVPool.device_tables``), never a full re-upload per step.  When
  the pool runs dry at a dispatch boundary the engine **preempts** the
  youngest lane instead of truncating: its pages are freed, and the
  request is re-queued at the front with its generated-so-far tokens as a
  resume prefix — on re-admission it re-prefills ``prompt + prefix`` and
  continues.  ``finish_reason="cache_full"`` survives only for the logical
  per-request capacity ``max_len`` (the page-table width), never for pool
  pressure.  The host-side allocator lives in ``serving.kv_pool``.

Prefill is **bucketed and batched**: queued prompts admitted in the same
scheduling step are padded to a small static set of bucket lengths (powers
of two up to ``max_len`` by default) and each bucket group is prefilled in
one jitted call.  Architectures with recurrent state (SSM / RG-LRU) cannot
absorb padding tokens into their state, so they group by *exact* prompt
length instead — still one batched prefill per group.  Chunked prefill is
batched the same way: one chunk dispatch per scheduling step absorbs a
chunk of *every* currently-chunking lane.

Mesh-native serving
-------------------
Pass ``mesh=`` (a ``("data", "model")`` mesh, e.g. from
``launch.mesh.make_local_mesh``) and the engine becomes tensor-parallel
end to end: every executable — prefill, chunked prefill, and the K-step
decode scan — is jitted with **explicit in/out NamedShardings**, and the
live params / cache / token buffer are ``device_put`` to match, so GSPMD
partitions the whole serving path instead of replicating it.

- **Weights** are TP-sharded by the serving pspec seam
  (``distributed.compressed_pspecs``): dense leaves follow the training
  rules with FSDP off (decode reads every weight each step), and each
  ``CompressedTensor`` leaf derives its spec from the dense rule for the
  same name — TP on the non-compressed (output) dim by default, on the
  compressed (reduction) dim only when the dense dim divides by
  ``M × axis_size`` so no N:M group straddles a shard.  Per-leaf
  ``sanitize_spec`` degrades odd dims to replication instead of erroring.
  The compressed artifact is served *sharded*: no dense or
  fully-replicated weight leaf is ever materialized (inspect with
  :meth:`sharding_report`).
- **KV caches are sequence-sharded** on the ``model`` axis
  (``kv_shard="seq"``, the ``cache_pspecs`` rule measured 75x cheaper in
  collectives than head-sharding): slab caches split the per-lane
  sequence axis; the paged pool splits its *pages* axis, so each shard
  physically owns a slice of the pool while the (replicated) page tables
  resolve logical→physical addresses locally on every shard.  Decode
  attention computes per-shard partial flash stats and the softmax
  combines via tiny psums — only ``(B, H)``-sized stats cross the
  interconnect, never cache pages.  The engine installs a
  ``kernels.dispatch.mesh_context`` around every executable call, so
  sharded pools (``PagedLayout.shards > 1``) route to the shard_map
  wrapper (``kernels.sharded``: per-shard table remap + the Pallas grid
  walk + an explicit flash-stat combine) whenever the inner route is a
  kernel body; the GSPMD-partitioned XLA gathered path remains the
  correctness backstop and the off-TPU default.  Reduction-TP'd
  compressed leaves are stamped with their shard count
  (``annotate_reduction_tp``) so ``nm_spmm`` takes the per-shard route
  the same way.  :meth:`kernel_route` reports the resolved route.
- **Degenerate 1×1 meshes are bit-identical** to the mesh-less engine:
  every sharding becomes trivial and the executables lower to the exact
  single-device programs, so ``mesh=None`` and a one-device mesh (and, in
  practice, any mesh shape — locked by tests/test_sharded_serving.py)
  produce the same greedy token streams.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cache import SlabLayout
from repro.models.model import (
    TransformerLM,
    _block_mixer_mlp,
    layer_plan,
    reset_lanes,
)
from repro.serving.kv_pool import PagedKVPool
from repro.serving.sampling import (
    SamplingParams,
    advance_stops,
    filtered_probs,
    request_keys,
    sample_tokens,
    spec_accept,
)
from repro.sparse_infer.compress import CompressedTensor


def _tree_stored_bytes(tree) -> int:
    """HBM bytes of a parameter tree as stored: ``CompressedTensor``
    leaves at their compressed (values + indices) size."""
    return sum(
        int(leaf.nbytes)
        for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, CompressedTensor)
        )
    )


@contextlib.contextmanager
def _quiet_donation():
    """Buffer donation is a no-op on backends without aliasing support
    (CPU); the stream is identical either way, so JAX's per-executable
    warning is noise — suppressed only around the engine's own dispatches
    (never globally: other code's donation bugs should still warn)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


@dataclasses.dataclass
class GenerationResult:
    """A completed request."""

    uid: int
    prompt: list[int]
    tokens: list[int]  # generated tokens (eos not included)
    finish_reason: str  # "eos" | "length" | "cache_full"


@dataclasses.dataclass
class _Request:
    uid: int
    prompt: list[int]
    sampling: SamplingParams
    # tokens generated before a preemption; on admission the engine
    # prefills prompt + prefix and generation continues after them
    prefix: list[int] = dataclasses.field(default_factory=list)


class _Slot:
    """Host-side bookkeeping for one active batch lane."""

    __slots__ = ("uid", "prompt", "sampling", "generated", "pos", "seq",
                 "pending", "feed")

    def __init__(self, req: _Request, pos: int, seq: int,
                 pending: Optional[list[int]] = None, feed: bool = False):
        self.uid = req.uid
        self.prompt = req.prompt
        self.sampling = req.sampling
        self.generated: list[int] = list(req.prefix)
        self.pos = pos  # host mirror of cache["len"][lane]
        self.seq = seq  # admission order; preemption evicts youngest first
        # chunked prefill: prompt(+prefix) tokens not yet absorbed into the
        # cache; the lane joins decode once this drains
        self.pending: list[int] = pending or []
        # device-scheduler refill: pending drains token-by-token *on
        # device* (fed from the staged buffer inside the while-loop), not
        # through the host's chunked-prefill dispatches
        self.feed = feed


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class DecodeEngine:
    """Batched decode over a slab or paged cache with continuous batching.

    Parameters
    ----------
    model: the ``TransformerLM`` wrapper.
    params: the serving tree — dense arrays and/or ``CompressedTensor``
        leaves; served directly, no rehydration.
    max_batch: number of concurrent decode lanes.
    max_len: logical per-request cache capacity (prompt + generated).
    kv_pool / num_pages / page_size: enable the paged layout — pass a
        prebuilt ``PagedKVPool`` or just ``num_pages`` (+ optional
        ``page_size``, default 16) to have the engine build one.
    steps_per_dispatch: decode steps fused into one on-device scan (K).
        The host syncs once per K tokens; admission/preemption happen at
        dispatch boundaries.  Greedy streams are bit-identical across K.
    max_steps_per_dispatch: enable the device-resident scheduler — the
        fixed-K scan becomes a run-until-stop ``lax.while_loop`` bounded
        by this many steps per dispatch (see "Device-resident
        scheduling" in the module docstring).  ``None`` (default) keeps
        the fixed-K sync scheduler.  Streams are bit-identical across
        schedulers.
    staged_lanes: device scheduler only — queued prompts pre-staged on
        device per cycle, so a lane that freezes mid-loop refills (and
        starts prefilling the staged prompt) inside the same dispatch.
        0 disables on-device refill.
    async_stream: device scheduler only — double-buffer dispatches: the
        next while-loop launches before the previous one's token block
        is fetched, so the host read overlaps device decode.
    donate: donate the cache pytree + token buffer into the jitted
        executables so the cache updates in place (no per-step full-cache
        copy).  ``False`` keeps the copying baseline; streams are
        bit-identical either way.
    prefill_chunk: absorb prompts longer than this in fixed-size chunks
        interleaved with decode dispatches (attention-family archs only;
        ignored for recurrent-state and sliding-window archs).
    prefill_buckets: static prompt-pad lengths for batched prefill
        (default: powers of two up to ``max_len``).  Ignored for archs
        with recurrent state, which group by exact prompt length.
    max_prefill_batch: cap on requests prefetched into one batched
        prefill (default ``max_batch``).
    mesh: optional ``("data", "model")`` mesh — serve tensor-parallel with
        sequence/pages-sharded KV caches (see "Mesh-native serving" in the
        module docstring).  A 1×1 mesh degenerates bit-identically to
        ``mesh=None``.
    kv_shard: ``"seq"`` (default; slab sequence axis / paged pages axis
        over ``model``) or ``"feature"`` (trailing head/latent dim) —
        the ``cache_pspecs`` layouts.  ``"feature"`` is rejected on
        meshes with a model axis > 1: its prefill write miscompiles under
        the SPMD partitioner (see ``compressed_pspecs.check_kv_shard``).
    prefix_cache: index every fully-prefilled prompt's pages in a radix
        trie (``serving.prefix_cache.PrefixIndex``); later requests
        sharing a prefix map the cached pages into their table (shared,
        refcounted, copy-on-write on divergence) and prefill only the
        uncached tail.  Paged + append-only + attention-family only;
        silently ignored (with a warning) otherwise.
    kv_quant: store KV pages as int8 with per-page-row scales (f16
        storage, f32 compute)
        (``models.cache.PagedLayout.quant``) — ~4x smaller pool at equal
        page count, dequantized inside the attention kernels.  Greedy
        streams may differ from fp pools within quantization tolerance.
    spec_gamma: enable self-speculative decoding — ``params`` becomes the
        *drafter* (the N:M-compressed artifact) and each scheduling step
        runs one speculative round: a gamma-step drafter scan proposes
        tokens per lane, then ONE chunked verify pass through
        ``verify_params`` scores all gamma+1 positions, accepts the
        longest valid draft prefix (greedy: argmax match; sampled: the
        standard rejection rule) and emits one trailing verifier token —
        so output distributions are *exactly* the verifier's, and greedy
        streams are bit-identical to plain decoding under
        ``verify_params``.  Pass an int >= 1 or ``"auto"`` (roofline pick,
        :meth:`pick_spec_gamma`).  Prefill / chunked prefill also run the
        verifier, so every committed KV entry is verifier-fidelity; the
        drafter's transient in-round KV writes are rewritten by the verify
        pass, and rejected tails are rolled back (``cache["len"]`` rewind
        on device + ``PagedKVPool.rollback`` host-side).  Sync scheduler
        + attention-family, non-windowed archs only.
    verify_params: the verifier tree for ``spec_gamma`` — the dense
        source weights, or a higher-fidelity N:M artifact (e.g. 4:8
        verifying a 2:4 drafter).  Mesh-native like ``params`` (its
        leaves take the serving pspec rules via
        ``verifier_param_shardings``).
    """

    def __init__(
        self,
        model: TransformerLM,
        params: Any,
        *,
        max_batch: int = 8,
        max_len: int = 128,
        seed: int = 0,
        kv_pool: Optional[PagedKVPool] = None,
        num_pages: Optional[int] = None,
        page_size: int = 16,
        steps_per_dispatch: int = 1,
        max_steps_per_dispatch: Optional[int] = None,
        staged_lanes: int = 0,
        async_stream: bool = False,
        donate: bool = True,
        prefill_chunk: Optional[int] = None,
        prefill_buckets: Optional[Sequence[int]] = None,
        max_prefill_batch: Optional[int] = None,
        mesh=None,
        kv_shard: str = "seq",
        prefix_cache: bool = False,
        kv_quant: bool = False,
        spec_gamma=None,
        verify_params: Any = None,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh
        self.kv_shard = kv_shard
        if steps_per_dispatch < 1:
            raise ValueError(f"steps_per_dispatch must be >= 1, got {steps_per_dispatch}")
        self.steps_per_dispatch = steps_per_dispatch
        self.donate = donate
        # device-resident scheduler configuration.  The write horizon H is
        # the most positions any lane can append between two host syncs:
        # k_loop steps per dispatch times the number of in-flight
        # dispatches per cycle (2 when async double-buffering).  All page
        # reservation (live-lane runway and staged-refill exposure) is
        # sized by H, which is what keeps mid-loop writes on mapped pages.
        self._device = max_steps_per_dispatch is not None
        if self._device and max_steps_per_dispatch < 1:
            raise ValueError(
                f"max_steps_per_dispatch must be >= 1, got {max_steps_per_dispatch}"
            )
        if (staged_lanes or async_stream) and not self._device:
            raise ValueError(
                "staged_lanes/async_stream need the device scheduler: "
                "pass max_steps_per_dispatch="
            )
        if staged_lanes < 0:
            raise ValueError(f"staged_lanes must be >= 0, got {staged_lanes}")
        self.k_loop = max_steps_per_dispatch
        self.staged_lanes = staged_lanes
        self.async_stream = async_stream
        self._w = 2 if async_stream else 1
        self._horizon = (
            self.k_loop * self._w if self._device else steps_per_dispatch
        )
        # chunked-prefill gating must precede pool construction: windowed
        # chunking sizes the pool's window-ring lookahead by the chunk
        windowed_arch = model.cfg.local_window is not None
        plan = layer_plan(model.cfg)
        kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
        # recurrent state cannot absorb pad tokens: group by exact length
        self._exact_prefill = any(
            _block_mixer_mlp(k, model.cfg)[0] in ("ssm", "rec") for k in kinds
        )
        # chunked prefill needs every mixer to read mid-prompt state from
        # the cache: attention-family only.  Windowed archs additionally
        # need the paged layout (the windowed chunk view reads the
        # window-ring page table; the slab has no ring to view)
        self._chunk_ok = (
            prefill_chunk is not None
            and not self._exact_prefill
            and (not windowed_arch or kv_pool is not None or num_pages is not None)
        )
        # -- speculative decoding: sparse drafter, higher-fidelity verifier --
        self._spec = spec_gamma is not None
        self._draft_params = None
        self.spec_gamma = 0
        if self._spec:
            if verify_params is None:
                raise ValueError(
                    "spec_gamma needs verify_params= — the dense (or "
                    "higher-fidelity N:M) tree the drafts are verified "
                    "against"
                )
            if windowed_arch:
                raise ValueError(
                    "spec_gamma is not supported on sliding-window archs: "
                    "a rejected draft cannot be rolled back out of the "
                    "window ring (slid-past pages are already evicted); "
                    "drop spec_gamma for this architecture"
                )
            if self._exact_prefill:
                raise ValueError(
                    "spec_gamma is not supported on SSM/RG-LRU archs: "
                    "recurrent state advanced by a rejected draft cannot "
                    "be rolled back; drop spec_gamma for this architecture"
                )
            if self._device:
                raise ValueError(
                    "spec_gamma needs the sync scheduler: drop "
                    "max_steps_per_dispatch/staged_lanes/async_stream "
                    "(a speculative round is already one host sync per "
                    "gamma+1 tokens)"
                )
            w_d = _tree_stored_bytes(params)
            w_v = _tree_stored_bytes(verify_params)
            if spec_gamma == "auto":
                spec_gamma = self.pick_spec_gamma(w_d, w_v)
            spec_gamma = int(spec_gamma)
            if spec_gamma < 1:
                raise ValueError(
                    f"spec_gamma must be >= 1 or 'auto', got {spec_gamma}"
                )
            if spec_gamma >= max_len:
                raise ValueError(
                    f"spec_gamma {spec_gamma} >= max_len {max_len}"
                )
            self.spec_gamma = spec_gamma
            self._spec_draft_bytes = w_d
            self._spec_verify_bytes = w_v
            # one round writes gamma+1 positions past the committed length
            # (gamma draft slots + the verify bonus slot): size the page
            # reservation horizon to cover the whole round, so
            # _ensure_capacity's per-lane clamp reserves exactly the pages
            # the round can touch and rollback releases the rejected tail
            self._horizon = max(self._horizon, spec_gamma + 1)
            # prefill, chunked prefill, and the verify pass all run the
            # *verifier* tree — every committed KV entry and every emitted
            # distribution is the verifier's; the drafter only steers
            # which tokens get proposed.  From here on self.params IS the
            # verifier and the drafter rides in _draft_params.
            self._draft_params = params
            params = verify_params
            self.params = params
        if kv_pool is None and num_pages is not None:
            lookahead = max(steps_per_dispatch, self._horizon)
            if self._chunk_ok and windowed_arch:
                # windowed chunk writes walk the window ring csz slots per
                # chunk; lookahead >= csz keeps them collision-free with
                # the positions the chunk view still reads
                lookahead = max(lookahead, prefill_chunk)
            kv_pool = PagedKVPool(
                model, max_batch=max_batch, max_len=max_len,
                num_pages=num_pages, page_size=page_size,
                lookahead=lookahead, mesh=mesh, kv_shard=kv_shard,
                quant=kv_quant,
            )
        if kv_quant and kv_pool is not None and not kv_pool.layout.quant:
            raise ValueError(
                "kv_quant=True needs a pool built with quant=True (pass "
                "quant= to PagedKVPool, or let the engine build it)"
            )
        self.pool = kv_pool
        if self.pool is not None:
            if self.pool.layout.lookahead < steps_per_dispatch:
                raise ValueError(
                    f"pool lookahead {self.pool.layout.lookahead} < "
                    f"steps_per_dispatch {steps_per_dispatch}; build the pool "
                    "with lookahead >= K"
                )
            if self._device and self.pool.layout.lookahead < self._horizon:
                raise ValueError(
                    f"pool lookahead {self.pool.layout.lookahead} < write "
                    f"horizon {self._horizon} (max_steps_per_dispatch x "
                    f"{self._w} in-flight dispatches); build the pool with "
                    "lookahead >= the horizon"
                )
            if (
                self._chunk_ok
                and windowed_arch
                and self.pool.layout.lookahead < prefill_chunk
            ):
                warnings.warn(
                    "windowed chunked prefill disabled: pool lookahead "
                    f"{self.pool.layout.lookahead} < prefill_chunk "
                    f"{prefill_chunk} (the window ring would recycle pages "
                    "the chunk view still reads)"
                )
                self._chunk_ok = False
            if mesh is not None and (
                self.pool.mesh is not mesh
                or getattr(self.pool, "kv_shard", kv_shard) != kv_shard
            ):
                raise ValueError(
                    "a mesh-native engine needs a pool built with the same "
                    "mesh and kv_shard (pass mesh=/kv_shard= to PagedKVPool, "
                    "or let the engine build it via num_pages=...)"
                )
            self.layout = self.pool.layout
            self.cache = self.pool.cache
        else:
            self.layout = SlabLayout(max_len)
            self.cache = model.init_cache(max_batch, max_len)
        # mesh-native serving: every executable below is jitted with explicit
        # in/out NamedShardings derived from the serving pspec seam, and the
        # live params / cache / token buffer are device_put to match.  A 1x1
        # mesh makes every sharding trivial, so the executables degenerate
        # bit-identically to the mesh=None path.
        self._shardings: Optional[dict] = None
        if mesh is not None:
            from repro.distributed.compressed_pspecs import (
                annotate_reduction_tp,
                check_kv_shard,
                lane_sharding,
                replicated,
                serving_cache_shardings,
                serving_param_shardings,
                verifier_param_shardings,
            )

            check_kv_shard(mesh, kv_shard)
            # stamp reduction-TP'd compressed leaves with their model-axis
            # shard count BEFORE deriving shardings: rshards lives in the
            # pytree aux, so the spec tree must be built from the annotated
            # tree to match leaf-for-leaf under device_put / in_shardings
            params = annotate_reduction_tp(params, mesh, cfg=model.cfg)
            self._shardings = {
                # in spec mode params is the *verifier*; its (dense or
                # compressed) leaves take the same serving placement seam
                "params": (
                    verifier_param_shardings(mesh, params, cfg=model.cfg)
                    if self._spec
                    else serving_param_shardings(mesh, params, cfg=model.cfg)
                ),
                # a mesh-native pool already derived (and applied) the
                # cache sharding tree — reuse it rather than re-walking
                "cache": (
                    self.pool.cache_shardings
                    if self.pool is not None
                    and self.pool.cache_shardings is not None
                    else serving_cache_shardings(
                        mesh, self.cache, self.layout, kv_shard=kv_shard
                    )
                ),
                "lane": lane_sharding(mesh, max_batch),
                "repl": replicated(mesh),
            }
            self.params = jax.device_put(params, self._shardings["params"])
            if self._spec:
                # the drafter tree is mesh-native too: same pspec seam, so
                # the draft scan and the verify pass run on one mesh with
                # no resharding between them
                dtree = annotate_reduction_tp(
                    self._draft_params, mesh, cfg=model.cfg
                )
                self._shardings["draft_params"] = serving_param_shardings(
                    mesh, dtree, cfg=model.cfg
                )
                self._draft_params = jax.device_put(
                    dtree, self._shardings["draft_params"]
                )
            if self.pool is None:
                self.cache = jax.device_put(self.cache, self._shardings["cache"])

        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.queue: deque[_Request] = deque()
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        if self._shardings is not None:
            self.tokens = jax.device_put(self.tokens, self._shardings["lane"])
        # the base sampling key is never split: per-token keys derive from
        # it as fold_in(fold_in(base, uid), token_index) (request_keys), so
        # streams are scheduler- and batch-mix-independent
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self._admit_seq = 0
        self.decode_steps = 0  # logical token steps actually executed
        self.dispatches = 0  # jitted decode calls
        self.cycles = 0  # device-scheduler cycles (full-drain host syncs)
        self.refills = 0  # on-device lane refills from the staged ring
        self.block_fetches = 0  # device->host token-block reads
        # staged-but-unconsumed queue entries for on-device refill:
        # [{"req": _Request, "rec": stage_alloc record | None,
        #   "tokens": np(S,), "len": int}] — rebuilt every cycle
        self._staged: list[dict] = []
        # seam for tests: how a device token block becomes host numpy
        # (forced-slow reads exercise async double-buffer ordering)
        self._fetch_block = lambda b: np.asarray(b)
        # inter-token latency: wall-clock deltas between consecutive
        # emissions of the same request, recorded at absorb time
        self._itl_ms: list[float] = []
        self._last_emit: dict[int, float] = {}
        self.admitted = 0
        self.preemptions = 0
        self.prefix_hits = 0  # admissions that reused cached prefix pages
        self.prefix_hit_tokens = 0  # prompt tokens skipped via the index
        # speculative-decoding accounting (spec_gamma only)
        self.spec_rounds = 0  # draft-scan + verify-pass round trips
        self.draft_tokens = 0  # tokens the drafter proposed
        self.verify_tokens = 0  # positions the verifier scored
        self.accepted_draft_tokens = 0  # proposals that survived verify
        self.spec_emitted_tokens = 0  # tokens actually absorbed via spec
        self._spec_req: dict[int, list[int]] = {}  # uid -> [drafted, accepted]
        self.max_concurrency = 0
        self.prefill_batches = 0
        self.prefill_chunks = 0  # chunked-prefill dispatches
        self.tokens_generated = 0
        self.decode_tokens = 0  # tokens produced by decode steps (not prefill)
        self.decode_wall_s = 0.0  # dispatch wall time (device + launch)
        self.sched_host_s = 0.0  # host scheduling time around dispatches
        self._util_sum = 0.0
        self._util_n = 0
        self._kv_bytes_sum = 0.0  # live KV bytes summed over decode steps
        self._kv_row_b: Optional[tuple[int, int]] = None  # _kv_row_bytes cache
        # slot-change-triggered host constants (temps/topks/eos/active/keep
        # and the static sampling flags are rebuilt only when the slot set
        # changes, not per dispatch)
        self._slots_dirty = True
        self._consts: Optional[dict] = None

        self.prefill_chunk = prefill_chunk if self._chunk_ok else None
        # windowed chunking maps window-ring pages chunk-by-chunk
        # (alloc_prefill defers them; _advance_chunks reserves per chunk)
        self._win_chunk = self.prefill_chunk is not None and windowed_arch
        # prefix caching rides the chunked-prefill machinery (a prefix-hit
        # lane is admitted as "already absorbed its first chunks" and the
        # uncached tail drains through _advance_chunks), so it carries the
        # same arch gate — attention-family, non-windowed — plus an
        # append-only full table (windowed pools evict shared pages).
        self._prefix = None
        if prefix_cache:
            lay = self.pool.layout if self.pool is not None else None
            if (
                lay is not None
                and lay.has_full and not lay.win
                and not self._exact_prefill
                and model.cfg.local_window is None
            ):
                from repro.serving.prefix_cache import PrefixIndex

                self._prefix = PrefixIndex(self.pool, lay.page_size)
            else:
                warnings.warn(
                    "prefix_cache=True ignored: needs a paged append-only "
                    "full table on an attention-family, non-windowed arch"
                )
        # tail prefill of a prefix hit reuses the chunk executable even when
        # chunked prefill itself is off — pick a chunk size for that case
        self._tail_chunk = self.prefill_chunk or min(64, max_len)
        if prefill_buckets:
            buckets = sorted(int(b) for b in prefill_buckets if 0 < int(b) <= max_len)
        else:
            buckets, b = [], 8
            while b < max_len:
                buckets.append(b)
                b *= 2
        if not buckets or buckets[-1] < max_len:
            buckets.append(max_len)
        self.prefill_buckets = tuple(buckets)
        self.max_prefill_batch = max_prefill_batch or max_batch

        layout = self.layout
        eng_max_len = max_len
        n_lanes = max_batch
        n_staged = max(1, staged_lanes)

        def _decode(params, tok, cache, temps, topks, active, keep, key,
                    eos, budget, uids, counts, k, need_sample, need_topk):
            # K decode steps fused into one on-device scan: embed → attend →
            # sample → scatter-into-cache → stop-detect, K times, one host
            # sync.  ``active`` lanes decode; ``keep`` lanes (occupied but
            # not decoding, e.g. mid chunked-prefill) hold their length;
            # free lanes pin to 0 so they cannot creep past the cache bound.
            # Sampling keys derive per row from (uid, generated-token
            # index); ``counts`` advances with each sampled token so the
            # stream is independent of how dispatches are cut.
            def body(carry, _):
                tok, cache, active, budget, counts = carry
                len_prev = cache["len"]
                logits, cache = model.decode_step(params, tok, cache, layout)
                cache["len"] = jnp.where(
                    active, cache["len"], jnp.where(keep, len_prev, 0)
                )
                keys = request_keys(key, uids, counts)
                nxt = sample_tokens(
                    logits, temps, topks, keys,
                    need_sample=need_sample, need_topk=need_topk,
                    rowwise=True,
                )
                counts = counts + active.astype(counts.dtype)
                nxt, active, budget = advance_stops(
                    nxt, active, budget, eos, cache["len"], eng_max_len
                )
                return (nxt, cache, active, budget, counts), nxt

            (tok, cache, active, budget, counts), block = jax.lax.scan(
                body, (tok, cache, active, budget, counts), None, length=k
            )
            return block, tok, cache

        def _prefill(params, tokens, lens, lanes, cache, temps, topks, key,
                     uids, counts, need_sample, need_topk):
            # one jitted call per (bucket_len, group_size): forward the whole
            # padded group, write each row's cache into its lane through the
            # layout, and sample each row's first token at position len-1
            # under that row's (uid, token-index) key
            logits_all, _, produced = model.forward(
                params, {"tokens": tokens}, remat=False, want_cache=True
            )
            idx = jnp.clip(lens - 1, 0, tokens.shape[1] - 1)
            logits = jnp.take_along_axis(logits_all, idx[:, None, None], axis=1)[:, 0]
            cache = model.write_prefill(cache, produced, lanes, lens, layout)
            first = sample_tokens(
                logits, temps, topks, request_keys(key, uids, counts),
                need_sample=need_sample, need_topk=need_topk, rowwise=True,
            )
            return first, cache

        def _dloop(params, cache, dstate, key, k_max, need_sample, need_topk):
            # device-resident scheduler: one while-loop iteration is one
            # decode step for every live lane — feeding lanes consume their
            # staged prompt token-by-token, drained lanes sample — followed
            # by at most one dead-lane refill from the staged ring.  The
            # loop exits on the step bound, on a freeze the refill did not
            # cover (the host must schedule), or when nothing is live and
            # nothing is staged.  The host reads back only (block, steps,
            # consumed_lane, consumed_step); the scheduler state chains
            # device-side between dispatches and is rebuilt from host
            # bookkeeping at every cycle boundary.  The state crosses the
            # jit boundary *packed* — same-dtype lane/ring vectors stacked
            # into a few matrices — so a cycle pays a handful of host→
            # device transfers instead of ~25; rows unpack here at trace
            # time for free.
            B, S, Q = n_lanes, eng_max_len, n_staged
            li = dstate["lanes_i"]
            ring = dstate["ring_i"]
            s_len, s_uid, s_count0 = ring[0], ring[1], ring[2]
            s_topks, s_eos, s_budget = ring[3], ring[4], ring[5]
            s_temps, s_tokens = dstate["s_temps"], dstate["s_tokens"]
            s_avail = dstate["scal"][1]

            def cond(c):
                more = jnp.any(c["live"]) | (c["s_next"] < s_avail)
                return (c["t"] < k_max) & more & ~c["stall"]

            def body(c):
                t = c["t"]
                cache = c["cache"]
                live, occupied = c["live"], c["occupied"]
                pend, fed, feed_buf = c["pend"], c["fed"], c["feed_buf"]
                feeding = pend > 0
                feed = jnp.where(
                    feeding,
                    feed_buf[jnp.arange(B), jnp.clip(fed, 0, S - 1)],
                    c["tok"],
                )
                len_prev = cache["len"]
                logits, cache = model.decode_step(params, feed, cache, layout)
                cache["len"] = jnp.where(
                    live, cache["len"], jnp.where(occupied, len_prev, 0)
                )
                pend = jnp.where(feeding, pend - 1, pend)
                fed = fed + feeding.astype(fed.dtype)
                # a lane samples the step its prompt drains — the feed of
                # the last prompt token doubles as the first-token forward
                sample_now = live & (pend == 0)
                keys = request_keys(key, c["uids"], c["counts"])
                nxt = sample_tokens(
                    logits, c["temps"], c["topks"], keys,
                    need_sample=need_sample, need_topk=need_topk,
                    rowwise=True,
                )
                counts = c["counts"] + sample_now.astype(c["counts"].dtype)
                tokens_out, act_out, budget = advance_stops(
                    nxt, sample_now, c["budget"], c["eos"], cache["len"],
                    eng_max_len,
                )
                tok = jnp.where(sample_now, tokens_out, c["tok"])
                nf = sample_now & ~act_out  # newly frozen lanes
                live = act_out | (pend > 0)
                occupied = occupied | nf
                block = c["block"].at[t].set(tokens_out)
                # at most one refill per iteration: swap the first dead
                # lane for the next staged request, entirely on device
                free = ~live
                do = (c["s_next"] < s_avail) & jnp.any(free)
                lane = jnp.argmax(free).astype(jnp.int32)
                row = jnp.clip(c["s_next"], 0, Q - 1)
                lm = (jnp.arange(B) == lane) & do
                uids = jnp.where(lm, s_uid[row], c["uids"])
                temps = jnp.where(lm, s_temps[row], c["temps"])
                topks = jnp.where(lm, s_topks[row], c["topks"])
                eos = jnp.where(lm, s_eos[row], c["eos"])
                budget = jnp.where(lm, s_budget[row], budget)
                counts = jnp.where(lm, s_count0[row], counts)
                pend = jnp.where(lm, s_len[row], pend)
                fed = jnp.where(lm, 0, fed)
                feed_buf = jnp.where(
                    lm[:, None], s_tokens[row][None, :], feed_buf
                )
                cache["len"] = jnp.where(lm, 0, cache["len"])
                tbl = cache.get("tables")
                if tbl is not None and "s_tbl_full" in dstate and "full" in tbl:
                    tbl["full"] = jnp.where(
                        lm[:, None], dstate["s_tbl_full"][row][None, :],
                        tbl["full"],
                    )
                if tbl is not None and "s_tbl_win" in dstate and "win" in tbl:
                    tbl["win"] = jnp.where(
                        lm[:, None], dstate["s_tbl_win"][row][None, :],
                        tbl["win"],
                    )
                cache = reset_lanes(model.cfg, cache, lm)
                live = live | lm
                occupied = occupied | lm
                consumed_lane = jnp.where(
                    do, c["consumed_lane"].at[row].set(lane),
                    c["consumed_lane"],
                )
                consumed_step = jnp.where(
                    do, c["consumed_step"].at[row].set(t),
                    c["consumed_step"],
                )
                s_next = c["s_next"] + do.astype(c["s_next"].dtype)
                # a freeze the refill did not cover stalls the loop: the
                # host has to admit / restage at the next cycle boundary
                stall = c["stall"] | jnp.any(nf & ~lm)
                return {
                    "t": t + 1, "tok": tok, "cache": cache, "live": live,
                    "occupied": occupied, "pend": pend, "fed": fed,
                    "counts": counts, "budget": budget, "uids": uids,
                    "temps": temps, "topks": topks, "eos": eos,
                    "feed_buf": feed_buf, "s_next": s_next, "stall": stall,
                    "block": block, "consumed_lane": consumed_lane,
                    "consumed_step": consumed_step,
                }

            init = {
                "t": jnp.asarray(0, jnp.int32),
                "tok": li[0], "cache": cache,
                "live": li[1].astype(bool), "occupied": li[2].astype(bool),
                "pend": li[3], "fed": li[4],
                "counts": li[5], "budget": li[6],
                "uids": li[7], "temps": dstate["temps"],
                "topks": li[8], "eos": li[9],
                "feed_buf": dstate["feed_buf"],
                "s_next": dstate["scal"][0],
                "stall": jnp.asarray(False),
                "block": jnp.zeros((k_max, B), jnp.int32),
                "consumed_lane": jnp.full((Q,), -1, jnp.int32),
                "consumed_step": jnp.full((Q,), -1, jnp.int32),
            }
            f = jax.lax.while_loop(cond, body, init)
            dstate = dict(dstate)
            dstate["lanes_i"] = jnp.stack(
                [f["tok"], f["live"].astype(jnp.int32),
                 f["occupied"].astype(jnp.int32), f["pend"], f["fed"],
                 f["counts"], f["budget"], f["uids"], f["topks"], f["eos"]]
            )
            dstate["temps"] = f["temps"]
            dstate["feed_buf"] = f["feed_buf"]
            dstate["scal"] = jnp.stack([f["s_next"], s_avail])
            return (f["block"], f["t"], f["consumed_lane"],
                    f["consumed_step"], dstate, f["cache"])

        def _chunk(params, tokens, cache, lanes, starts, lengths):
            # one dispatch absorbs a chunk of every currently-chunking lane
            return model.prefill_chunk(
                params, tokens, cache, lanes, starts, lengths, layout
            )

        def _sdraft(dparams, tok, cache, temps, topks, gi, keep, key, uids,
                    counts, g, need_sample, need_topk):
            # speculative draft scan: the fused-decode body re-run under
            # the drafter tree with per-lane step masks — lane i proposes
            # only its first gi[i] steps (gi = 0 freezes it; it still gets
            # the verify pass's bonus token).  Proposals are NOT
            # commitments: cache["len"] rewinds to the round's start so
            # the verify chunk rescores (and rewrites at verifier
            # fidelity) every drafted position.  Draft keys live on their
            # own fold_in stream, independent of the verify pass's
            # accept/residual draws.
            len0 = cache["len"]
            dkey = jax.random.fold_in(key, 1)

            def body(carry, t):
                tok, cache, counts = carry
                len_prev = cache["len"]
                drafting = t < gi
                logits, cache = model.decode_step(dparams, tok, cache, layout)
                cache["len"] = jnp.where(
                    drafting, cache["len"], jnp.where(keep, len_prev, 0)
                )
                keys = request_keys(dkey, uids, counts)
                nxt = sample_tokens(
                    logits, temps, topks, keys,
                    need_sample=need_sample, need_topk=need_topk,
                    rowwise=True,
                )
                nxt = jnp.where(drafting, nxt, 0)
                if need_sample:
                    # the drafter's post-filter distribution at each
                    # proposal, for the rejection rule; zeroed past gi so
                    # the residual at the bonus slot is the verifier's
                    # own distribution
                    probs = filtered_probs(
                        logits, temps, topks, need_topk=need_topk
                    )
                    probs = jnp.where(drafting[:, None], probs, 0.0)
                else:
                    probs = jnp.zeros((n_lanes, 1), jnp.float32)
                counts = counts + drafting.astype(counts.dtype)
                tok = jnp.where(drafting, nxt, tok)
                return (tok, cache, counts), (nxt, probs)

            (_, cache, _), (drafts, dprobs) = jax.lax.scan(
                body, (tok, cache, counts), jnp.arange(g)
            )
            cache["len"] = jnp.where(keep, len0, 0)
            return drafts, dprobs, cache

        def _sverify(vparams, tok, drafts, dprobs, cache, temps, topks,
                     active, key, uids, counts, gi, g, need_sample,
                     need_topk):
            # speculative verify: ONE chunked-prefill dispatch through the
            # verifier scores all gamma+1 positions — row i feeds its last
            # committed token plus its drafts at starts = the committed
            # length, (re)writing verifier-fidelity KV over every draft
            # slot while all_logits=True unembeds the whole chunk.  Slot j
            # of the logits is the verifier distribution for the token
            # AFTER input j, so the accept rule, the trailing
            # correction/bonus token, and the committed-length rewind all
            # resolve on device; the host fetches only (block, n_acc).
            b = n_lanes
            len0 = cache["len"]
            rows = jnp.concatenate([tok[:, None], drafts.T], axis=1)
            lanes = jnp.where(active, jnp.arange(b), b).astype(jnp.int32)
            lengths = jnp.where(active, gi + 1, 0).astype(jnp.int32)
            logits_all, cache = model.prefill_chunk(
                vparams, rows, cache, lanes, len0.astype(jnp.int32),
                lengths, layout, all_logits=True,
            )
            tb = jnp.broadcast_to(temps[:, None], (b, g + 1))
            kb = jnp.broadcast_to(topks[:, None], (b, g + 1))
            p_ver = filtered_probs(logits_all, tb, kb, need_topk=need_topk)
            akeys = request_keys(jax.random.fold_in(key, 2), uids, counts)
            rkeys = request_keys(jax.random.fold_in(key, 3), uids, counts)
            block, n_acc = spec_accept(
                drafts.T, jnp.moveaxis(dprobs, 0, 1), p_ver, gi,
                akeys, rkeys, need_sample=need_sample,
            )
            block = jnp.where(active[:, None], block, 0)
            n_acc = jnp.where(active, n_acc, 0)
            # device half of the rollback: committed length = accepted
            # prefix + the trailing emitted token (whose KV is written
            # next round, like any freshly sampled token); stale draft KV
            # past it is dead under the length masks.  prefill_chunk
            # advanced active lanes to len0 + gi + 1 — rewind them.
            cache["len"] = jnp.where(active, len0 + n_acc + 1, cache["len"])
            last = jnp.take_along_axis(block, n_acc[:, None], axis=1)[:, 0]
            tok = jnp.where(active, last, tok)
            return block, n_acc, tok, cache

        # the need_* flags are static so all-greedy batches compile to a
        # bare argmax (no vocab sort / categorical in the decode hot path);
        # at most 4 _decode variants exist, warmed untimed on first use.
        # donate_argnums hands the cache (and the decode's token buffer) to
        # XLA for in-place update — without it every dispatch copies the
        # whole pool because the engine reuses the input cache.
        jit_kw: dict = {"decode": {}, "prefill": {}, "chunk": {},
                        "dloop": {}, "sdraft": {}, "sverify": {}}
        if self._shardings is not None:
            # pin explicit in/out shardings on every executable: params TP,
            # cache seq/pages-sharded, per-lane vectors over DP, prefill /
            # chunk row batches replicated (they scatter into the sharded
            # cache), rng keys replicated.  The device scheduler's state
            # dict is all scheduling metadata (a few KB) — replicated via
            # a prefix sharding rather than lane-split for simplicity.
            from jax.sharding import NamedSharding, PartitionSpec as _P

            psh = self._shardings["params"]
            csh = self._shardings["cache"]
            lane = self._shardings["lane"]
            repl = self._shardings["repl"]
            blk = NamedSharding(mesh, _P(None, *tuple(lane.spec)))
            jit_kw["decode"] = dict(
                in_shardings=(psh, lane, csh, lane, lane, lane, lane, repl,
                              lane, lane, lane, lane),
                out_shardings=(blk, lane, csh),
            )
            jit_kw["prefill"] = dict(
                in_shardings=(psh, repl, repl, repl, csh, repl, repl, repl,
                              repl, repl),
                out_shardings=(repl, csh),
            )
            jit_kw["chunk"] = dict(
                in_shardings=(psh, repl, csh, repl, repl, repl),
                out_shardings=(repl, csh),
            )
            jit_kw["dloop"] = dict(
                in_shardings=(psh, csh, repl, repl),
                out_shardings=(repl, repl, repl, repl, repl, csh),
            )
            if self._spec:
                # drafter params live under their own sharding map; the
                # per-step draft probs carry a trailing vocab axis (kept
                # unsharded — only read back through the verify pass)
                prb = NamedSharding(mesh, _P(None, *tuple(lane.spec), None))
                rowsh = NamedSharding(mesh, _P(*tuple(lane.spec), None))
                psh_d = self._shardings["draft_params"]
                jit_kw["sdraft"] = dict(
                    in_shardings=(psh_d, lane, csh, lane, lane, lane, lane,
                                  repl, lane, lane),
                    out_shardings=(blk, prb, csh),
                )
                jit_kw["sverify"] = dict(
                    in_shardings=(psh, lane, blk, prb, csh, lane, lane,
                                  lane, repl, lane, lane, lane),
                    out_shardings=(rowsh, lane, lane, csh),
                )
        # statics are passed *positionally* (static_argnums): pjit rejects
        # kwargs outright once in_shardings is specified
        self._decode = jax.jit(
            _decode,
            static_argnums=(12, 13, 14),  # k, need_sample, need_topk
            donate_argnums=(1, 2) if donate else (),
            **jit_kw["decode"],
        )
        self._prefill = jax.jit(
            _prefill,
            static_argnums=(10, 11),  # need_sample, need_topk
            donate_argnums=(4,) if donate else (),
            **jit_kw["prefill"],
        )
        self._chunk = jax.jit(
            _chunk, donate_argnums=(2,) if donate else (), **jit_kw["chunk"]
        )
        self._dloop = jax.jit(
            _dloop,
            static_argnums=(4, 5, 6),  # k_max, need_sample, need_topk
            donate_argnums=(1, 2) if donate else (),
            **jit_kw["dloop"],
        )
        if self._spec:
            # _sdraft keeps tok alive (the verify pass needs it as the
            # chunk's first row), so only the cache is donated; _sverify
            # consumes both tok and the drafted cache.
            self._sdraft = jax.jit(
                _sdraft,
                static_argnums=(10, 11, 12),  # g, need_sample, need_topk
                donate_argnums=(2,) if donate else (),
                **jit_kw["sdraft"],
            )
            self._sverify = jax.jit(
                _sverify,
                static_argnums=(12, 13, 14),  # g, need_sample, need_topk
                donate_argnums=(1, 4) if donate else (),
                **jit_kw["sverify"],
            )
        self._warmed: set[tuple] = set()

    # -- request intake ------------------------------------------------------

    def submit(
        self, prompt: Sequence[int], sampling: Optional[SamplingParams] = None
    ) -> int:
        """Enqueue a request; returns its uid."""
        prompt = [int(t) for t in prompt]
        sampling = sampling or SamplingParams()
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= cache capacity {self.max_len}"
            )
        if self.pool is not None:
            cap = min(len(prompt) + sampling.max_new_tokens, self.max_len)
            need = self.pool.pages_for_request(cap)
            if need > self.pool.layout.num_pages:
                raise ValueError(
                    f"request needs up to {need} pages but the pool has only "
                    f"{self.pool.layout.num_pages}; raise --num-pages or "
                    "lower max_new_tokens"
                )
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(_Request(uid, prompt, sampling))
        return uid

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _finish(self, i: int, reason: str, out: list[GenerationResult]) -> None:
        s = self.slots[i]
        out.append(GenerationResult(s.uid, s.prompt, s.generated, reason))
        self.tokens_generated += len(s.generated)
        self.slots[i] = None
        self._slots_dirty = True
        self._last_emit.pop(s.uid, None)
        if self.pool is not None:
            self.pool.release(i)

    def _absorb(
        self, i: int, token: int, out: list[GenerationResult], *,
        from_decode: bool = False,
    ) -> None:
        """Record a freshly sampled token for slot i; finish on a stop.

        These rules are mirrored on device by ``sampling.advance_stops``
        (the K-step scan's freeze logic) — keep the two in lockstep."""
        s = self.slots[i]
        sp = s.sampling
        if sp.eos_id >= 0 and token == sp.eos_id:
            self._finish(i, "eos", out)
            return
        s.generated.append(token)
        now = time.perf_counter()
        last = self._last_emit.get(s.uid)
        if last is not None:
            self._itl_ms.append((now - last) * 1e3)
        self._last_emit[s.uid] = now
        if from_decode:
            self.decode_tokens += 1
        if len(s.generated) >= sp.max_new_tokens:
            self._finish(i, "length", out)
        elif len(s.prompt) + len(s.generated) >= self.max_len:
            # the request hit its logical capacity (page-table width /
            # slab length) — distinct from pool pressure, which preempts
            self._finish(i, "cache_full", out)

    def _preempt(self, i: int, out: list[GenerationResult]) -> None:
        """Evict lane i: free its pages, requeue it with a resume prefix."""
        s = self.slots[i]
        self.slots[i] = None
        self._slots_dirty = True
        self.pool.release(i)
        self.preemptions += 1
        self.queue.appendleft(
            _Request(s.uid, s.prompt, s.sampling, prefix=list(s.generated))
        )

    def _bucket(self, n: int) -> int:
        if self._exact_prefill:
            return n
        for b in self.prefill_buckets:
            if b >= n:
                return b
        return self.prefill_buckets[-1]

    def _admit(self, out: list[GenerationResult]) -> None:
        """Move queued requests into lanes; one batched prefill per bucket.

        Prompts longer than ``prefill_chunk`` take the chunked route: the
        lane is claimed (and its pages reserved) now, but the prompt is
        absorbed chunk-by-chunk across the following scheduling steps.

        With a prefix index, admission first asks it for the longest
        cached prefix (page granularity): the hit pages are mapped shared
        into the lane's table and only the uncached tail is absorbed —
        through the chunked machinery, since a prefix-hit lane is exactly
        a lane that already absorbed its first chunks."""
        picked: list[tuple[_Request, int, int]] = []
        n_taken = 0
        while self.queue and n_taken < self.max_prefill_batch:
            i = self._free_slot()
            if i is None:
                break
            req = self.queue[0]
            seq = list(req.prompt) + list(req.prefix)
            length = len(seq)
            chunked = (
                self.prefill_chunk is not None and length > self.prefill_chunk
            )
            # a windowed chunked admission defers its window-ring mapping:
            # the ring slots are claimed chunk-by-chunk (_advance_chunks)
            # as the window slides over the prompt
            defer = chunked and self._win_chunk
            shared_len, shared_pids = 0, ()
            if self._prefix is not None:
                shared_len, shared_pids = self._prefix.match(seq)
            if self.pool is not None:
                ok = self.pool.alloc_prefill(
                    i, length, shared_full=shared_pids, shared_len=shared_len,
                    defer_win=defer,
                )
                # pool pressure: shed LRU index entries before giving up —
                # each evict() can invalidate matched pages, so re-match
                while (
                    not ok
                    and self._prefix is not None
                    and self._prefix.evict(1)
                ):
                    shared_len, shared_pids = self._prefix.match(seq)
                    ok = self.pool.alloc_prefill(
                        i, length, shared_full=shared_pids,
                        shared_len=shared_len, defer_win=defer,
                    )
                if not ok:
                    break  # retry next step, after frees/preemptions
            self.queue.popleft()
            n_taken += 1
            if shared_len > 0:
                # prefix hit: absorb only the uncached tail, chunk-wise
                self.prefix_hits += 1
                self.prefix_hit_tokens += shared_len
                self.slots[i] = _Slot(
                    req, pos=shared_len, seq=self._admit_seq,
                    pending=seq[shared_len:],
                )
                self._admit_seq += 1
                self.admitted += 1
                self._slots_dirty = True
                continue
            if chunked:
                self.slots[i] = _Slot(
                    req, pos=0, seq=self._admit_seq,
                    pending=seq,
                )
                self._admit_seq += 1
                self.admitted += 1
                self._slots_dirty = True
                continue
            self.slots[i] = _Slot(req, pos=length, seq=self._admit_seq)
            self._admit_seq += 1
            self._slots_dirty = True
            picked.append((req, i, length))
        if not picked:
            return
        groups: dict[int, list[tuple[_Request, int, int]]] = {}
        for item in picked:
            groups.setdefault(self._bucket(item[2]), []).append(item)
        for lb in sorted(groups):
            self._prefill_group(lb, groups[lb], out)

    def _prefill_group(
        self, lb: int, items: list[tuple[_Request, int, int]],
        out: list[GenerationResult],
    ) -> None:
        nb = _next_pow2(len(items))
        tokens = np.zeros((nb, lb), np.int32)
        lens = np.zeros((nb,), np.int32)
        lanes = np.full((nb,), self.max_batch, np.int32)  # sentinel = pad row
        temps = np.zeros((nb,), np.float32)
        topks = np.zeros((nb,), np.int32)
        uids = np.zeros((nb,), np.int32)
        counts = np.zeros((nb,), np.int32)
        for r, (req, i, length) in enumerate(items):
            tokens[r, :length] = req.prompt + req.prefix
            lens[r] = length
            lanes[r] = i
            temps[r] = req.sampling.temperature
            topks[r] = req.sampling.top_k
            uids[r] = req.uid
            # first sampled token's index: resume prefixes already hold
            # the request's first len(prefix) generated tokens
            counts[r] = len(req.prefix)
        need_sample = any(req.sampling.temperature > 0 for req, _, _ in items)
        need_topk = any(req.sampling.top_k > 0 for req, _, _ in items)
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        with self._kernel_ctx(), _quiet_donation():
            first, self.cache = self._prefill(
                self.params, jnp.asarray(tokens), jnp.asarray(lens),
                jnp.asarray(lanes), self.cache, jnp.asarray(temps),
                jnp.asarray(topks), self.key, jnp.asarray(uids),
                jnp.asarray(counts), need_sample, need_topk,
            )
        if self.pool is not None:
            # the donated call consumed the table buffers the pool held;
            # re-anchor its incremental sync on the returned arrays
            self.pool.adopt_tables(self.cache.get("tables"))
        self.tokens = self.tokens.at[lanes].set(first, mode="drop")
        self.prefill_batches += 1
        host_first = np.asarray(first)
        if self._prefix is not None:
            # index the freshly written pages while the lane still maps
            # them (_absorb may finish the lane and release its claim;
            # the index's own references keep the KV resident)
            for req, i, length in items:
                full, tail = self.pool.prompt_pages(i, length)
                self._prefix.insert(
                    req.prompt + req.prefix, full, tail,
                    length % self.pool.layout.page_size,
                )
        for r, (req, i, _) in enumerate(items):
            self.admitted += 1
            self._absorb(i, int(host_first[r]), out)

    def _advance_chunks(self, out: list[GenerationResult]) -> None:
        """One prompt chunk of *every* chunk-prefilling lane per scheduling
        step, absorbed by a single batched dispatch (rows padded to a power
        of two with sentinel lanes, so the executable retraces O(log B)
        times, not per lane count).  Previously each chunking lane cost its
        own dispatch per step.

        A lane's final chunk's logits seed its request's first sampled
        token, so a lane never idles fully-prefilled-but-unsampled across a
        dispatch.
        """
        # prefix-hit lanes drain their uncached tail here even when chunked
        # prefill proper is off — _tail_chunk covers that case.  Refill-fed
        # lanes (s.feed) drain on device instead, never through this path.
        csz = self.prefill_chunk or self._tail_chunk
        chunking = [
            i for i, s in enumerate(self.slots)
            if s is not None and s.pending and not s.feed
        ]
        if not chunking:
            return
        if self._win_chunk and self.pool is not None:
            # windowed chunk writes walk the window ring: claim this
            # chunk's ring slots now (full pages were mapped whole at
            # admission; alloc_prefill deferred the ring).  Pool pressure
            # preempts youngest-first, like the decode runway reservation.
            for i in list(chunking):
                s = self.slots[i]
                if s is None:
                    continue
                k = min(csz, len(s.pending))
                while self.slots[i] is not None and not self.pool.ensure_steps(
                    i, self.slots[i].pos, k
                ):
                    victim = max(
                        (j for j, t_ in enumerate(self.slots)
                         if t_ is not None),
                        key=lambda j: self.slots[j].seq,
                    )
                    self._preempt(victim, out)
                    if victim == i:
                        break
            chunking = [i for i in chunking if self.slots[i] is not None]
            if not chunking:
                return
        nb = _next_pow2(len(chunking))
        toks = np.zeros((nb, csz), np.int32)
        lanes = np.full((nb,), self.max_batch, np.int32)  # sentinel = pad row
        starts = np.zeros((nb,), np.int32)
        lengths = np.zeros((nb,), np.int32)
        for r, i in enumerate(chunking):
            s = self.slots[i]
            part = s.pending[:csz]
            toks[r, : len(part)] = part
            lanes[r] = i
            starts[r] = s.pos
            lengths[r] = len(part)
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        with self._kernel_ctx(), _quiet_donation():
            logits, self.cache = self._chunk(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(lanes), jnp.asarray(starts), jnp.asarray(lengths),
            )
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        self.prefill_chunks += 1
        finishing: list[tuple[int, int]] = []  # (row, lane)
        for r, i in enumerate(chunking):
            s = self.slots[i]
            took = int(lengths[r])
            s.pos += took
            s.pending = s.pending[took:]
            if not s.pending:
                finishing.append((r, i))
        if finishing and self._prefix is not None:
            # the lane's whole prompt(+resume prefix) is now cached: index
            # its pages before _absorb can finish/release the lane
            for _, i in finishing:
                s = self.slots[i]
                full, tail = self.pool.prompt_pages(i, s.pos)
                self._prefix.insert(
                    s.prompt + s.generated, full, tail,
                    s.pos % self.pool.layout.page_size,
                )
        if finishing:
            temps = np.zeros((nb,), np.float32)
            topks = np.zeros((nb,), np.int32)
            uids = np.zeros((nb,), np.int32)
            counts = np.zeros((nb,), np.int32)
            for r, i in finishing:
                s = self.slots[i]
                temps[r] = s.sampling.temperature
                topks[r] = s.sampling.top_k
                uids[r] = s.uid
                counts[r] = len(s.generated)
            keys = request_keys(
                self.key, jnp.asarray(uids), jnp.asarray(counts)
            )
            first = sample_tokens(
                logits, jnp.asarray(temps), jnp.asarray(topks), keys,
                need_sample=bool((temps > 0).any()),
                need_topk=bool((topks > 0).any()),
                rowwise=True,
            )
            host_first = np.asarray(first)
            for r, i in finishing:
                self.tokens = self.tokens.at[i].set(first[r])
                self._slots_dirty = True
                self._absorb(i, int(host_first[r]), out)

    def _ensure_capacity(self, out: list[GenerationResult]) -> None:
        """Back every decoding lane's next K writes; preempt on pressure.

        Lanes are served oldest-first and victims chosen youngest-first, so
        the oldest request always makes progress (a request that could
        never fit alone is rejected at submit).  Reserving the whole
        dispatch up front (``ensure_steps``) is what rules out mid-scan
        pool exhaustion."""
        if self.pool is None:
            return
        order = sorted(
            (
                i for i, s in enumerate(self.slots)
                if s is not None and (not s.pending or s.feed)
            ),
            key=lambda i: self.slots[i].seq,
        )
        for i in order:
            s = self.slots[i]
            if s is None:  # already evicted as an earlier lane's victim
                continue
            # a lane whose remaining token budget is < the horizon freezes
            # on device before the loop ends — don't reserve (and
            # potentially preempt someone for) pages its writes will never
            # reach.  Refill-fed lanes also write their still-pending
            # prompt tokens; every lane stops at the logical capacity.
            k = max(
                1,
                min(
                    self._horizon,
                    len(s.pending)
                    + max(1, s.sampling.max_new_tokens - len(s.generated)),
                    self.max_len - s.pos,
                ),
            )
            while self.slots[i] is not None and not self.pool.ensure_steps(
                i, self.slots[i].pos, k
            ):
                # cached-but-idle prefix pages are cheaper to give up than
                # a live lane: shed LRU index entries before preempting
                if self._prefix is not None and self._prefix.evict(1):
                    continue
                victim = max(
                    (j for j, t in enumerate(self.slots) if t is not None),
                    key=lambda j: self.slots[j].seq,
                )
                self._preempt(victim, out)
                if victim == i:
                    break

    def _slot_consts(self) -> dict:
        """Per-lane device constants, rebuilt only when the slot set changes
        (not per dispatch — the per-step rebuild was pure host overhead)."""
        if not self._slots_dirty and self._consts is not None:
            return self._consts
        decode = [s is not None and not s.pending for s in self.slots]
        keep = [s is not None for s in self.slots]
        self._consts = {
            "active_np": np.array(decode),
            "active": jnp.asarray(np.array(decode)),
            "keep": jnp.asarray(np.array(keep)),
            "temps": jnp.asarray(
                [
                    s.sampling.temperature if (s and not s.pending) else 0.0
                    for s in self.slots
                ],
                jnp.float32,
            ),
            "topks": jnp.asarray(
                [
                    s.sampling.top_k if (s and not s.pending) else 0
                    for s in self.slots
                ],
                jnp.int32,
            ),
            "eos": jnp.asarray(
                [
                    s.sampling.eos_id if (s and not s.pending) else -1
                    for s in self.slots
                ],
                jnp.int32,
            ),
            "uids": jnp.asarray(
                [s.uid if s else 0 for s in self.slots], jnp.int32
            ),
            "need_sample": any(
                s is not None and not s.pending and s.sampling.temperature > 0
                for s in self.slots
            ),
            "need_topk": any(
                s is not None and not s.pending and s.sampling.top_k > 0
                for s in self.slots
            ),
        }
        self._slots_dirty = False
        return self._consts

    def step(self) -> list[GenerationResult]:
        """One scheduling step: admit what fits, advance chunked prefills,
        run one decode dispatch (fixed-K scan) or one device-scheduler
        cycle (run-until-stop while-loops); return finished requests."""
        if self._spec:
            return self._step_spec()
        if self._device:
            return self._step_device()
        out: list[GenerationResult] = []
        self._admit(out)
        if self.prefill_chunk is not None or self._prefix is not None:
            self._advance_chunks(out)
        t_prefill_done = time.perf_counter()
        self._ensure_capacity(out)
        consts = self._slot_consts()
        active = consts["active_np"]
        self.max_concurrency = max(self.max_concurrency, int(active.sum()))
        if not active.any():
            return out
        self._util_sum += self._cache_utilization()
        self._util_n += 1
        self._kv_bytes_sum += self._live_kv_bytes()
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        k = self.steps_per_dispatch
        budget = np.zeros((self.max_batch,), np.int32)
        counts = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and not s.pending:
                budget[i] = s.sampling.max_new_tokens - len(s.generated)
                counts[i] = len(s.generated)
        args = (
            self.params, self.tokens, self.cache, consts["temps"],
            consts["topks"], consts["active"], consts["keep"], self.key,
            consts["eos"], jnp.asarray(budget), consts["uids"],
            jnp.asarray(counts),
        )
        sig = (k, consts["need_sample"], consts["need_topk"])
        t_sched = time.perf_counter()  # warmup compile time is not host overhead
        if ("decode",) + sig not in self._warmed:
            # untimed warmup: trace+compile of this variant must not land in
            # decode_wall_s (it would dominate ms_per_decode_step on short
            # runs).  The warmup runs on *copies* of the donated operands so
            # the originals stay valid for the timed call, whose result is
            # the one absorbed.
            wargs = args
            if self.donate:
                tok_c, cache_c = jax.tree_util.tree_map(
                    jnp.copy, (args[1], args[2])
                )
                wargs = (args[0], tok_c, cache_c) + args[3:]
            with self._kernel_ctx(), _quiet_donation():
                jax.block_until_ready(self._decode(*wargs, *sig))
            self._warmed.add(("decode",) + sig)
        t0 = time.perf_counter()
        with self._kernel_ctx(), _quiet_donation():
            block, tok, self.cache = self._decode(*args, *sig)
            tok.block_until_ready()
        t1 = time.perf_counter()
        self.decode_wall_s += t1 - t0
        self.decode_steps += k
        self.dispatches += 1
        self.tokens = tok
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        host_block = self._fetch_block(block)  # (K, B): one sync per K tokens
        self.block_fetches += 1
        live = [i for i in range(self.max_batch) if active[i]]
        for t in range(k):
            for i in list(live):
                self.slots[i].pos += 1  # mirror cache["len"] advancing
            for i in list(live):
                self._absorb(i, int(host_block[t, i]), out, from_decode=True)
                if self.slots[i] is None:
                    live.remove(i)
        t_end = time.perf_counter()
        self.sched_host_s += (t_sched - t_prefill_done) + (t_end - t1)
        return out

    # -- speculative decoding ------------------------------------------------

    @staticmethod
    def pick_spec_gamma(draft_bytes: int, verify_bytes: int, *,
                        alpha: float = 0.75, g_max: int = 16) -> int:
        """Roofline choice of the draft length for ``spec_gamma="auto"``.

        A round moves ``g * draft_bytes`` (one drafter sweep per proposed
        token) plus ``verify_bytes`` (one verifier sweep scores all g+1
        positions) and commits ``E[gain] = (1 - alpha^(g+1)) / (1 - alpha)``
        tokens under an i.i.d. per-token acceptance rate ``alpha`` (the
        standard speculative-decoding progress model).  Minimising bytes
        per accepted token balances drafter cheapness against wasted work
        on rejection; alpha defaults to 0.75, a typical magnitude-pruned
        drafter's agreement with its dense parent.
        """
        best_g, best_cost = 1, float("inf")
        for g in range(1, g_max + 1):
            if alpha >= 1.0:
                exp_tok = float(g + 1)
            else:
                exp_tok = (1.0 - alpha ** (g + 1)) / (1.0 - alpha)
            cost = (g * draft_bytes + verify_bytes) / exp_tok
            if cost < best_cost:
                best_g, best_cost = g, cost
        return best_g

    def _step_spec(self) -> list[GenerationResult]:
        """One speculative round: gamma drafter decode steps (one fused
        scan dispatch) chained device-side into one verifier chunk
        dispatch; the host syncs ONCE per round, on the accepted block.
        Emits between 1 and gamma+1 tokens per live lane — output
        distributions are exactly the verifier's (longest-prefix accept
        under greedy, rejection sampling otherwise)."""
        out: list[GenerationResult] = []
        self._admit(out)
        if self.prefill_chunk is not None or self._prefix is not None:
            self._advance_chunks(out)
        t_prefill_done = time.perf_counter()
        self._ensure_capacity(out)  # horizon covers gamma+1 writes
        consts = self._slot_consts()
        active = consts["active_np"]
        self.max_concurrency = max(self.max_concurrency, int(active.sum()))
        if not active.any():
            return out
        self._util_sum += self._cache_utilization()
        self._util_n += 1
        self._kv_bytes_sum += self._live_kv_bytes()
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:
                self.cache["tables"] = dt
        g = self.spec_gamma
        counts = np.zeros((self.max_batch,), np.int32)
        gi = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None and not s.pending and active[i]:
                counts[i] = len(s.generated)
                rem = s.sampling.max_new_tokens - len(s.generated)
                # leave room for the verify pass's guaranteed token: a
                # lane with 1 token of budget or cache left drafts nothing
                # and still finishes via the bonus
                gi[i] = max(0, min(g, self.max_len - 1 - s.pos, rem - 1))
        gi_j = jnp.asarray(gi)
        counts_j = jnp.asarray(counts)
        sig = (g, consts["need_sample"], consts["need_topk"])
        draft_args = (
            self._draft_params, self.tokens, self.cache, consts["temps"],
            consts["topks"], gi_j, consts["keep"], self.key,
            consts["uids"], counts_j,
        )
        t_sched = time.perf_counter()
        if ("spec",) + sig not in self._warmed:
            # untimed warmup of both executables, on copies of the donated
            # operands (tok is donated by _sverify, cache by both)
            wargs = draft_args
            if self.donate:
                cache_c = jax.tree_util.tree_map(jnp.copy, draft_args[2])
                wargs = draft_args[:2] + (cache_c,) + draft_args[3:]
            with self._kernel_ctx(), _quiet_donation():
                dts, dps, cc = self._sdraft(*wargs, *sig)
                jax.block_until_ready(self._sverify(
                    self.params, jnp.copy(self.tokens), dts, dps, cc,
                    consts["temps"], consts["topks"], consts["active"],
                    self.key, consts["uids"], counts_j, gi_j, *sig,
                ))
            self._warmed.add(("spec",) + sig)
        t0 = time.perf_counter()
        with self._kernel_ctx(), _quiet_donation():
            drafts, dprobs, cache = self._sdraft(*draft_args, *sig)
            rows, n_acc, tok, self.cache = self._sverify(
                self.params, self.tokens, drafts, dprobs, cache,
                consts["temps"], consts["topks"], consts["active"],
                self.key, consts["uids"], counts_j, gi_j, *sig,
            )
            tok.block_until_ready()
        t1 = time.perf_counter()
        self.decode_wall_s += t1 - t0
        self.decode_steps += g + 1
        self.dispatches += 2  # draft scan + verify chunk
        self.spec_rounds += 1
        self.tokens = tok
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        host_rows = self._fetch_block(rows)  # (B, G+1) — the round's sync
        n_np = np.asarray(n_acc)
        self.block_fetches += 1
        live = [i for i in range(self.max_batch) if active[i]]
        for i in live:
            n = int(n_np[i])
            gii = int(gi[i])
            self.draft_tokens += gii
            self.verify_tokens += gii + 1
            self.accepted_draft_tokens += n
            s = self.slots[i]
            rec = self._spec_req.setdefault(s.uid, [0, 0])
            rec[0] += gii
            rec[1] += n
            for t in range(n + 1):
                if self.slots[i] is None:
                    break  # stop rule fired mid-block: drop the tail
                self.slots[i].pos += 1  # mirror cache["len"] advancing
                self.spec_emitted_tokens += 1
                self._absorb(i, int(host_rows[i, t]), out, from_decode=True)
        if self.pool is not None:
            # host half of the rollback: lanes that stopped early (or
            # rejected drafts) release full-table pages past their
            # committed length; freed lanes were already released whole
            # by _absorb
            for i in live:
                if self.slots[i] is not None:
                    self.pool.rollback(i, self.slots[i].pos)
        t_end = time.perf_counter()
        self.sched_host_s += (t_sched - t_prefill_done) + (t_end - t1)
        return out

    # -- device-resident scheduler -------------------------------------------

    def _stage_fill(self) -> None:
        """Pre-stage queued prompts for on-device lane refill.

        Pops up to ``staged_lanes`` requests and pre-reserves each one's
        first-cycle pages (``PagedKVPool.stage_alloc`` — exposure capped
        by the write horizon, so a mid-loop swap can never write an
        unmapped page).  The ring is rebuilt every cycle: whatever the
        loop does not consume is released and pushed back to the queue
        front at the cycle boundary (``_unstage``).  Staged admissions
        bypass the prefix index — they prefill token-by-token on device
        into fresh pages."""
        assert not self._staged
        while len(self._staged) < self.staged_lanes and self.queue:
            req = self.queue[0]
            seq = list(req.prompt) + list(req.prefix)
            budget = req.sampling.max_new_tokens - len(req.prefix)
            rec = None
            if self.pool is not None:
                rec = self.pool.stage_alloc(len(seq), budget, self._horizon)
                if rec is None:
                    break  # pool pressure: stop staging this cycle
            self.queue.popleft()
            toks = np.zeros((self.max_len,), np.int32)
            toks[: len(seq)] = seq
            self._staged.append(
                {"req": req, "rec": rec, "tokens": toks, "len": len(seq)}
            )

    def _unstage(self, skip: int = 0) -> None:
        """Return staged-but-unconsumed entries (ring rows >= ``skip``) to
        the queue front, releasing their pre-reserved pages."""
        rest = self._staged[skip:]
        self._staged = []
        for e in reversed(rest):
            if e["rec"] is not None:
                self.pool.release_staged(e["rec"])
            self.queue.appendleft(e["req"])

    def _build_dstate(self) -> dict:
        """Device scheduler state, rebuilt wholesale from host bookkeeping
        at every cycle boundary (the host never reads it back — only the
        token block and the consumed-refill records round-trip)."""
        B, S = self.max_batch, self.max_len
        Q = max(1, self.staged_lanes)
        tok = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        occupied = np.zeros((B,), bool)
        pend = np.zeros((B,), np.int32)
        counts = np.zeros((B,), np.int32)
        budget = np.zeros((B,), np.int32)
        uids = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        topks = np.zeros((B,), np.int32)
        eos = np.full((B,), -1, np.int32)
        feed_buf = np.zeros((B, S), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            occupied[i] = True
            uids[i] = s.uid
            temps[i] = s.sampling.temperature
            topks[i] = s.sampling.top_k
            eos[i] = s.sampling.eos_id
            counts[i] = len(s.generated)
            budget[i] = max(0, s.sampling.max_new_tokens - len(s.generated))
            if s.generated:
                tok[i] = s.generated[-1]
            if s.pending and s.feed:
                # mid-refill lane: the unfed prompt tail re-stages into
                # the lane's feed buffer and keeps draining on device
                feed_buf[i, : len(s.pending)] = s.pending
                pend[i] = len(s.pending)
                live[i] = True
            elif not s.pending:
                live[i] = True
            # host-chunked (non-feed) pending lanes stay occupied-not-live:
            # their length pins while the host keeps chunking next cycle
        s_tokens = np.zeros((Q, S), np.int32)
        s_len = np.zeros((Q,), np.int32)
        s_uid = np.zeros((Q,), np.int32)
        s_count0 = np.zeros((Q,), np.int32)
        s_temps = np.zeros((Q,), np.float32)
        s_topks = np.zeros((Q,), np.int32)
        s_eos = np.full((Q,), -1, np.int32)
        s_budget = np.zeros((Q,), np.int32)
        for r, e in enumerate(self._staged):
            req = e["req"]
            s_tokens[r] = e["tokens"]
            s_len[r] = e["len"]
            s_uid[r] = req.uid
            s_count0[r] = len(req.prefix)
            s_temps[r] = req.sampling.temperature
            s_topks[r] = req.sampling.top_k
            s_eos[r] = req.sampling.eos_id
            s_budget[r] = max(
                1, req.sampling.max_new_tokens - len(req.prefix)
            )
        # pack same-dtype vectors into stacked matrices: one host→device
        # transfer each instead of one per field (the jitted loop unpacks
        # rows at trace time).  row order is load-bearing — _dloop indexes
        # by position
        lanes_i = np.stack(
            [tok, live.astype(np.int32), occupied.astype(np.int32), pend,
             np.zeros((B,), np.int32),  # fed
             counts, budget, uids, topks, eos]
        )
        ring_i = np.stack([s_len, s_uid, s_count0, s_topks, s_eos, s_budget])
        d = {
            "lanes_i": jnp.asarray(lanes_i),
            "temps": jnp.asarray(temps),
            "feed_buf": jnp.asarray(feed_buf),
            "ring_i": jnp.asarray(ring_i),
            "s_temps": jnp.asarray(s_temps),
            "s_tokens": jnp.asarray(s_tokens),
            "scal": jnp.asarray([0, len(self._staged)], jnp.int32),
        }
        if self.pool is not None:
            lo = self.pool.layout
            if lo.has_full:
                s_tf = np.full((Q, lo.pages_full), lo.num_pages, np.int32)
                for r, e in enumerate(self._staged):
                    if e["rec"] is not None and e["rec"]["full_row"] is not None:
                        s_tf[r] = e["rec"]["full_row"]
                d["s_tbl_full"] = jnp.asarray(s_tf)
            if lo.win:
                s_tw = np.full((Q, lo.pages_win), lo.num_pages, np.int32)
                for r, e in enumerate(self._staged):
                    if e["rec"] is not None and e["rec"]["win_row"] is not None:
                        s_tw[r] = e["rec"]["win_row"]
                d["s_tbl_win"] = jnp.asarray(s_tw)
        return d

    def _replay(self, hb, steps: int, c_lane, c_step,
                out: list[GenerationResult]) -> int:
        """Mirror one dispatch's while-loop on the host: advance positions,
        absorb sampled tokens through the same stop rules the device
        applied (``_absorb``), and install refills at the iterations the
        device performed them.  Returns the number of staged ring rows
        this dispatch consumed."""
        by_step: dict[int, list[int]] = {}
        n = 0
        for r in range(c_lane.shape[0]):
            if c_step[r] >= 0:
                by_step.setdefault(int(c_step[r]), []).append(r)
                n += 1
        for t in range(steps):
            feeders: list[int] = []
            samplers: list[int] = []
            for i in range(self.max_batch):
                s = self.slots[i]
                if s is None:
                    continue
                if s.pending:
                    if s.feed:
                        feeders.append(i)
                    # host-chunked lanes froze on device: skip
                else:
                    samplers.append(i)
            for i in feeders + samplers:
                self.slots[i].pos += 1  # mirror cache["len"] advancing
            for i in feeders:
                s = self.slots[i]
                s.pending.pop(0)
                if not s.pending:
                    # the drain step also sampled the request's first token
                    self._absorb(i, int(hb[t, i]), out)
            for i in samplers:
                self._absorb(i, int(hb[t, i]), out, from_decode=True)
            for r in by_step.get(t, ()):
                # the device swapped staged ring row r into a dead lane at
                # the end of iteration t; its feeding starts at t+1
                lane = int(c_lane[r])
                e = self._staged[r]
                assert self.slots[lane] is None, (
                    "device refilled a lane the host still considers live"
                )
                if self.pool is not None and e["rec"] is not None:
                    self.pool.adopt_staged(lane, e["rec"])
                req = e["req"]
                self.slots[lane] = _Slot(
                    req, pos=0, seq=self._admit_seq,
                    pending=list(req.prompt) + list(req.prefix), feed=True,
                )
                self._admit_seq += 1
                self.admitted += 1
                self.refills += 1
                self._slots_dirty = True
        return n

    def _step_device(self) -> list[GenerationResult]:
        """One device-scheduler cycle: a full-drain host sync (admission,
        chunk drain, staging, runway reservation, state rebuild) followed
        by W chained run-until-stop dispatches (W=2 when async streaming),
        each fetched and replayed in launch order."""
        out: list[GenerationResult] = []
        self._admit(out)
        if self.prefill_chunk is not None or self._prefix is not None:
            # drain every host-chunked prompt before the (long) cycle: a
            # mid-chunk lane cannot join the device loop, and one chunk
            # per k_loop*W-step cycle would starve it
            while True:
                todo = sum(
                    len(s.pending) for s in self.slots
                    if s is not None and s.pending and not s.feed
                )
                if not todo:
                    break
                self._advance_chunks(out)
                left = sum(
                    len(s.pending) for s in self.slots
                    if s is not None and s.pending and not s.feed
                )
                if left >= todo:
                    break  # no progress (pool pressure): retry next cycle
        t_prefill_done = time.perf_counter()
        self._ensure_capacity(out)
        self._stage_fill()
        n_live = sum(
            1 for s in self.slots
            if s is not None and (not s.pending or s.feed)
        )
        self.max_concurrency = max(self.max_concurrency, n_live)
        if not n_live and not self._staged:
            return out
        self._util_sum += self._cache_utilization()
        self._util_n += 1
        self._kv_bytes_sum += self._live_kv_bytes()
        if self.pool is not None:
            if self.pool.pending_copies:
                self.cache = self.pool.apply_pending(self.cache)
            dt = self.pool.device_tables()
            if dt:  # ssm-only paged archs have no table'd layers
                self.cache["tables"] = dt
        dstate = self._build_dstate()
        need_sample = any(
            s is not None and s.sampling.temperature > 0 for s in self.slots
        ) or any(e["req"].sampling.temperature > 0 for e in self._staged)
        need_topk = any(
            s is not None and s.sampling.top_k > 0 for s in self.slots
        ) or any(e["req"].sampling.top_k > 0 for e in self._staged)
        sig = (self.k_loop, need_sample, need_topk)
        t_sched = time.perf_counter()
        if ("dloop",) + sig not in self._warmed:
            wargs = (self.params, self.cache, dstate, self.key)
            if self.donate:
                cache_c, dstate_c = jax.tree_util.tree_map(
                    jnp.copy, (self.cache, dstate)
                )
                wargs = (self.params, cache_c, dstate_c, self.key)
            with self._kernel_ctx(), _quiet_donation():
                jax.block_until_ready(self._dloop(*wargs, *sig))
            self._warmed.add(("dloop",) + sig)
        t0 = time.perf_counter()
        # launch all W dispatches up front: the scheduler state and cache
        # chain device-side, so dispatch w+1 is enqueued before dispatch
        # w's results exist — the double buffer async streaming rides on
        records = []
        cache = self.cache
        with self._kernel_ctx(), _quiet_donation():
            for _ in range(self._w):
                block, steps, c_lane, c_step, dstate, cache = self._dloop(
                    self.params, cache, dstate, self.key, *sig
                )
                records.append((block, steps, c_lane, c_step))
                self.dispatches += 1
        self.cache = cache
        if self.pool is not None:
            self.pool.adopt_tables(self.cache.get("tables"))
        t_launched = time.perf_counter()
        # fetch + replay in launch order: the block fetch of dispatch w
        # blocks on w alone, so host replay (and token streaming) of w
        # overlaps dispatch w+1 still executing on device
        consumed = 0
        fetch_s = 0.0
        host_s = 0.0
        for block, steps, c_lane, c_step in records:
            f0 = time.perf_counter()
            steps_i = int(steps)
            hb = self._fetch_block(block)
            c_lane_np = np.asarray(c_lane)
            c_step_np = np.asarray(c_step)
            f1 = time.perf_counter()
            self.block_fetches += 1
            self.decode_steps += steps_i
            consumed += self._replay(hb, steps_i, c_lane_np, c_step_np, out)
            host_s += time.perf_counter() - f1
            fetch_s += f1 - f0
        self.decode_wall_s += (t_launched - t0) + fetch_s
        # cycle boundary: retire the consumed ring prefix (adopted at
        # replay time), requeue the rest with their pages released
        self._unstage(skip=consumed)
        self.cycles += 1
        self.sched_host_s += (t_sched - t_prefill_done) + host_s
        return out

    def run(self) -> dict[int, GenerationResult]:
        """Drain the queue and all active slots; results keyed by uid."""
        results: dict[int, GenerationResult] = {}
        while self.queue or any(s is not None for s in self.slots):
            for r in self.step():
                results[r.uid] = r
        return results

    # -- reporting -----------------------------------------------------------

    def _cache_utilization(self) -> float:
        """Fraction of *reserved* cache token-slots holding live tokens.

        The slab reserves ``max_batch × max_len`` slots unconditionally;
        the paged pool reserves only its allocated pages — this ratio is
        what block-granular allocation buys on heterogeneous traffic.
        """
        lane_lens = {i: s.pos for i, s in enumerate(self.slots) if s is not None}
        if self.pool is not None:
            denom = self.pool.used_pages * self.pool.layout.page_size
            live = self.pool.live_tokens(lane_lens)
        else:
            denom = self.max_batch * self.max_len
            live = sum(min(p, self.max_len) for p in lane_lens.values())
        return live / denom if denom else 0.0

    def weight_bytes_per_step(self) -> int:
        """HBM weight bytes one decode step must read: every parameter leaf
        once, ``CompressedTensor`` leaves at their *stored* (compressed)
        size — the numerator of the N:M bandwidth win.  MoE archs overcount
        by the unrouted experts (all experts are resident; a step reads
        only top-k), so treat this as the dense-roofline bound.
        """
        total = 0
        for leaf in jax.tree_util.tree_leaves(
            self.params, is_leaf=lambda x: isinstance(x, CompressedTensor)
        ):
            total += int(leaf.nbytes)
        return total

    def _kv_row_bytes(self) -> tuple[int, int]:
        """(append-only, windowed) cache bytes per token per lane, summed
        over layers.  Constant for the engine's lifetime — computed once
        (step() calls this per decode step)."""
        if self._kv_row_b is not None:
            return self._kv_row_b
        cfg = self.model.cfg
        itemsize = jnp.dtype(cfg.param_dtype).itemsize
        plan = layer_plan(cfg)
        kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
        full_b = win_b = 0
        windowed = (
            cfg.local_window is not None and cfg.local_window <= self.max_len
        )
        for kind in kinds:
            mixer = _block_mixer_mlp(kind, cfg)[0]
            if mixer == "attn":
                rb = 2 * cfg.n_kv * cfg.hd * itemsize
                if windowed:
                    win_b += rb
                else:
                    full_b += rb
            elif mixer == "mla":
                full_b += (cfg.mla.kv_lora + cfg.mla.rope_head_dim) * itemsize
        self._kv_row_b = (full_b, win_b)
        return self._kv_row_b

    def _live_kv_bytes(self) -> int:
        """KV bytes the *paged fast path* reads this step: each active
        lane's live tokens once.  (The gathered reference reads — and
        rewrites — the full ``B × S_max`` view instead; the slab engine
        has no choice.  This is the bytes-read-per-step roofline input
        that ``benchmarks/serve_bench.py`` records.)"""
        full_b, win_b = self._kv_row_bytes()
        win = (
            min(self.max_len, self.model.cfg.local_window)
            if self.model.cfg.local_window is not None
            else self.max_len
        )
        total = 0
        for s in self.slots:
            if s is not None:
                total += full_b * min(s.pos + 1, self.max_len)
                total += win_b * min(s.pos + 1, win)
        return total

    def kv_cache_bytes(self) -> int:
        """Device bytes held by attention/MLA cache storage (slab or pool)."""
        plan = layer_plan(self.model.cfg)
        total = 0

        def entry_bytes(entry) -> int:
            return sum(x.nbytes for x in jax.tree_util.tree_leaves(entry))

        for i, kind in enumerate(plan.head):
            if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                total += entry_bytes(self.cache[f"head_{i}"])
        if plan.n_body:
            for j, kind in enumerate(plan.period):
                if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                    total += entry_bytes(self.cache["body"][f"sb_{j}"])
        for i, kind in enumerate(plan.tail):
            if _block_mixer_mlp(kind, self.model.cfg)[0] in ("attn", "mla"):
                total += entry_bytes(self.cache[f"tail_{i}"])
        return total

    def _kernel_ctx(self):
        """Dispatch mesh context for executable calls.  ``jax.jit``
        (re)traces lazily per signature, so the context must wrap *every*
        call, not just the first: any trace happening inside may route
        ``shards > 1`` kernel calls to the shard_map wrappers
        (``kernels.dispatch.mesh_context``).  A mesh-less engine gets a
        no-op context."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.kernels import dispatch

        return dispatch.mesh_context(self.mesh)

    def kernel_route(self) -> str:
        """The paged-attention route decode resolves at trace time —
        ``"shard_map"`` / ``"xla"`` / ``"pallas"`` / ``"interpret"`` for
        paged engines, ``"slab"`` when no paged kernel is in play.
        Mirrors the in-trace resolution (same mesh context + shape info)
        so benches can record which implementation a measured stream ran
        on without re-lowering the executable."""
        if self.pool is None:
            return "slab"
        from repro.kernels import dispatch

        lay = self.layout
        n_slots = lay.pages_full if lay.pages_full else lay.pages_win
        with self._kernel_ctx():
            mode, _ = dispatch.resolve(
                "paged_attn", b=self.max_batch, n_slots=n_slots,
                page_size=lay.page_size, num_pages=lay.num_pages,
                shards=lay.shards,
            )
        return mode

    def mesh_desc(self) -> Optional[dict]:
        """{"shape": [...], "axes": [...]} for the engine's mesh (None =
        single-device) — the schema serve_bench records under ``mesh``."""
        if self.mesh is None:
            return None
        return {
            "shape": [int(s) for s in self.mesh.devices.shape],
            "axes": [str(a) for a in self.mesh.axis_names],
        }

    def sharding_report(self, include_hlo: bool = False) -> dict:
        """Per-shard placement facts for the mesh-native engine.

        Reports, per weight/cache leaf and in aggregate, the bytes one
        shard holds (``sharding.shard_shape``) next to the global bytes —
        the per-shard HBM numbers the serve_bench sharded sweep records —
        plus which weight leaves ended up fully replicated (none should,
        for 2-D+ matmul weights on a model-axis mesh).  With
        ``include_hlo=True`` the decode executable is lowered + compiled
        for the engine's current shapes and its collective mix
        (all-reduce/all-gather/... counts and bytes) and per-argument input
        shardings are extracted — the "live executable" view the sharded
        serving tests assert on.
        """
        import math

        def shard_bytes(x) -> int:
            if self.mesh is not None and hasattr(x, "sharding"):
                return (
                    math.prod(x.sharding.shard_shape(x.shape))
                    * x.dtype.itemsize
                )
            return int(x.size * x.dtype.itemsize)

        from repro.utils.tree import _path_str

        weights = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.params):
            weights[_path_str(path)] = {
                "bytes": int(leaf.size * leaf.dtype.itemsize),
                "bytes_per_shard": shard_bytes(leaf),
                "ndim": int(leaf.ndim),
                "replicated": (
                    bool(leaf.sharding.is_fully_replicated)
                    if hasattr(leaf, "sharding") else True
                ),
            }

        def is_matmul_leaf(name: str, w: dict) -> bool:
            # per-feature vectors (norm scales, biases — stacked ones are
            # 2-D) replicate by design; counting them would bury a real
            # weight-replication regression in constant noise
            return w["ndim"] >= 2 and not any(
                f in name for f in ("bias", "norm", "scale")
            )
        cache_total = cache_shard = 0
        for leaf in jax.tree_util.tree_leaves(self.cache):
            cache_total += int(leaf.size * leaf.dtype.itemsize)
            cache_shard += shard_bytes(leaf)
        report = {
            "mesh": self.mesh_desc(),
            "weights": weights,
            "weight_bytes": sum(w["bytes"] for w in weights.values()),
            "weight_bytes_per_shard": sum(
                w["bytes_per_shard"] for w in weights.values()
            ),
            # the regression signal: matmul weights (ndim >= 2, not a
            # per-feature vector) that ended up fully replicated — 0 on a
            # healthy model-axis mesh
            "replicated_matmul_leaves": sum(
                1 for name, w in weights.items()
                if w["replicated"] and is_matmul_leaf(name, w)
            ),
            "cache_bytes": cache_total,
            "cache_bytes_per_shard": cache_shard,
        }
        if include_hlo:
            from repro.utils import hlo_cost as HC

            consts = self._slot_consts()
            budget = jnp.zeros((self.max_batch,), jnp.int32)
            counts = jnp.zeros((self.max_batch,), jnp.int32)
            with self._kernel_ctx():
                lowered = self._decode.lower(
                    self.params, self.tokens, self.cache, consts["temps"],
                    consts["topks"], consts["active"], consts["keep"],
                    self.key, consts["eos"], budget, consts["uids"], counts,
                    self.steps_per_dispatch, False, False,
                )
            compiled = lowered.compile()
            walk = HC.analyze(compiled.as_text())
            report["decode_collective_bytes"] = walk["collective_bytes"]
            report["decode_collective_total"] = walk["collective_total"]
            n_weight_leaves = len(jax.tree_util.tree_leaves(self.params))
            try:
                flat_in = jax.tree_util.tree_leaves(compiled.input_shardings[0])
                report["decode_weight_inputs_replicated"] = [
                    bool(s.is_fully_replicated)
                    for s in flat_in[:n_weight_leaves]
                ]
            except Exception:  # AOT introspection API drift: report omits it
                report["decode_weight_inputs_replicated"] = None
        return report

    def stats(self) -> dict:
        # throughput counts only decode-produced tokens over decode wall time;
        # each request's first token comes from (untimed) prefill and would
        # otherwise inflate tokens/s
        wb = self.weight_bytes_per_step()
        # _kv_bytes_sum is sampled once per host scheduling round: per
        # dispatch in sync mode, per cycle under the device scheduler
        kv_samples = self.cycles if self._device else self.dispatches
        kvb = self._kv_bytes_sum / kv_samples if kv_samples else 0.0
        total_wall = self.decode_wall_s + self.sched_host_s
        st = {
            "layout": self.layout.kind,
            "scheduler": "device" if self._device else "sync",
            "decode_steps": self.decode_steps,
            "dispatches": self.dispatches,
            "steps_per_dispatch": self.steps_per_dispatch,
            # a host sync is where scheduling can happen: every dispatch
            # in sync mode, one per round under speculation (draft+verify
            # chain device-side), only each full-drain cycle boundary
            # under the device scheduler
            "host_syncs": (
                self.cycles if self._device
                else (self.spec_rounds if self._spec else self.dispatches)
            ),
            "cycles": self.cycles,
            "block_fetches": self.block_fetches,
            "refills": self.refills,
            "max_steps_per_dispatch": self.k_loop,
            "staged_lanes": self.staged_lanes,
            "async_stream": self.async_stream,
            "itl_ms_p50": (
                float(np.percentile(self._itl_ms, 50)) if self._itl_ms else 0.0
            ),
            "itl_ms_p99": (
                float(np.percentile(self._itl_ms, 99)) if self._itl_ms else 0.0
            ),
            "donate": self.donate,
            "admitted": self.admitted,
            "preemptions": self.preemptions,
            "max_concurrency": self.max_concurrency,
            "prefill_batches": self.prefill_batches,
            "prefill_chunks": self.prefill_chunks,
            "tokens_generated": self.tokens_generated,
            "decode_tokens": self.decode_tokens,
            "decode_wall_s": self.decode_wall_s,
            "sched_host_s": self.sched_host_s,
            "kv_cache_bytes": self.kv_cache_bytes(),
            "hbm_cache_utilization": (
                self._util_sum / self._util_n if self._util_n else 0.0
            ),
            # per *logical token step*: device-side dispatch wall vs the
            # host-scheduling overhead amortized over the K tokens it buys
            "ms_per_decode_step": (
                self.decode_wall_s / self.decode_steps * 1e3
                if self.decode_steps
                else 0.0
            ),
            "ms_per_decode_step_host": (
                self.sched_host_s / self.decode_steps * 1e3
                if self.decode_steps
                else 0.0
            ),
            "host_overhead_frac": (
                self.sched_host_s / total_wall if total_wall > 0 else 0.0
            ),
            # decode-step roofline inputs: weight stream + mean live-KV read
            "weight_bytes_per_step": wb,
            "kv_bytes_per_step": kvb,
            "bytes_read_per_step": wb + kvb,
            "tokens_per_s": (
                self.decode_tokens / self.decode_wall_s
                if self.decode_wall_s > 0
                else 0.0
            ),
        }
        if self.pool is not None:
            lane_lens = {
                i: s.pos for i, s in enumerate(self.slots) if s is not None
            }
            used = self.pool.used_pages
            st["num_pages"] = self.pool.layout.num_pages
            st["page_size"] = self.pool.layout.page_size
            st["used_pages"] = used
            st["evicted_pages"] = self.pool.evicted_pages
            st["page_utilization"] = used / max(1, self.pool.layout.num_pages)
            live = self.pool.live_tokens(lane_lens)
            st["token_utilization"] = (
                live / (used * self.pool.layout.page_size) if used else 0.0
            )
            st["table_full_uploads"] = self.pool.table_full_uploads
            st["table_row_syncs"] = self.pool.table_row_syncs
            st["table_syncs"] = self.pool.table_syncs
            st["kv_quant"] = self.pool.layout.quant
            st["shared_pages"] = self.pool.shared_pages
            st["cow_copies"] = self.pool.cow_copies
        if self._prefix is not None:
            st["prefix_cache"] = True
            st["prefix_indexed_pages"] = self._prefix.pages
            st["prefix_evictions"] = self._prefix.evictions
            st["prefix_hits"] = self.prefix_hits
            st["prefix_hit_tokens"] = self.prefix_hit_tokens
            st["prefix_hit_rate"] = (
                self.prefix_hits / self.admitted if self.admitted else 0.0
            )
        if self._spec:
            w_d, w_v = self._spec_draft_bytes, self._spec_verify_bytes
            st["spec_gamma"] = self.spec_gamma
            st["spec_rounds"] = self.spec_rounds
            st["draft_tokens"] = self.draft_tokens
            st["verify_tokens"] = self.verify_tokens
            st["accepted_draft_tokens"] = self.accepted_draft_tokens
            st["spec_emitted_tokens"] = self.spec_emitted_tokens
            st["acceptance_rate"] = (
                self.accepted_draft_tokens / self.draft_tokens
                if self.draft_tokens else 0.0
            )
            st["accepted_per_verify"] = (
                self.spec_emitted_tokens / self.spec_rounds
                if self.spec_rounds else 0.0
            )
            st["draft_weight_bytes_per_step"] = w_d
            st["verify_weight_bytes_per_step"] = w_v
            # amortized weight stream per committed token: each round pays
            # gamma drafter sweeps + one verifier sweep
            st["bytes_per_accepted_token"] = (
                self.spec_rounds * (self.spec_gamma * w_d + w_v)
                / self.spec_emitted_tokens
                if self.spec_emitted_tokens else 0.0
            )
            st["spec_per_request"] = {
                uid: {
                    "drafted": d,
                    "accepted": a,
                    "acceptance_rate": a / d if d else 0.0,
                }
                for uid, (d, a) in sorted(self._spec_req.items())
            }
        return st
