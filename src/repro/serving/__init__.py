"""Compressed-native serving: continuous-batching decode over N:M trees."""
from repro.serving.engine import DecodeEngine, GenerationResult
from repro.serving.kv_pool import PagedKVPool
from repro.serving.prefix_cache import PrefixIndex
from repro.serving.sampling import SamplingParams, sample_tokens
