"""Layer-wise mixed N:M assignment (DominoSearch-style, paper Table 4).

DominoSearch (Sun et al., 2021) finds per-layer N (with a shared M) meeting a
global sparsity budget. This module implements the greedy energy variant the
paper combines STEP with: starting from dense, repeatedly decrement the N of
whichever layer loses the least magnitude-energy per parameter removed, until
the global kept-parameter budget is met. STEP itself is orthogonal (it does
not modify the ratio assignment — paper §6 Ablation I), so the output here is
just a ``SparsityConfig.layer_patterns`` list.
"""
from __future__ import annotations

import heapq
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import NMSparsity
from repro.core.sparsity_config import SparsityConfig
from repro.utils.tree import tree_paths


def _energy_at_n(w: np.ndarray, n: int, m: int, axis: int) -> float:
    """Fraction of squared-magnitude energy kept by an n:m mask along axis."""
    wt = np.moveaxis(np.asarray(w, np.float32), axis, -1)
    g = wt.reshape(wt.shape[:-1] + (wt.shape[-1] // m, m))
    sq = g**2
    part = np.sort(sq, axis=-1)[..., ::-1]  # descending
    kept = part[..., :n].sum()
    total = sq.sum() + 1e-30
    return float(kept / total)


def domino_search(
    params: Any,
    base: SparsityConfig,
    m: int = 8,
    target_density: float = 0.5,
    min_n: int = 1,
) -> SparsityConfig:
    """Assign per-layer N:m patterns meeting a global kept-parameter budget.

    ``target_density``: kept fraction over all *maskable* parameters
    (e.g. 0.25 for "Mixed N:8" at 2:8-average). Returns a new SparsityConfig
    whose ``layer_patterns`` pins each maskable leaf to its chosen N:m.
    """
    names = tree_paths(params)
    leaves = jax.tree_util.tree_leaves(params)
    layers = []  # (name, np_weight, axis, size)
    for name, p in zip(names, leaves):
        pat = base.pattern_for(name, tuple(p.shape))
        if pat is None:
            continue
        if p.shape[pat.group_axis % p.ndim] % m != 0:
            continue
        layers.append((name, np.asarray(p), pat.group_axis, int(p.size)))
    if not layers:
        return base

    total = sum(sz for *_, sz in layers)
    budget = target_density * total
    n_cur = {name: m for name, *_ in layers}
    kept = float(total)

    # precompute energy curves
    energy = {
        name: [
            _energy_at_n(w, n, m, axis) for n in range(0, m + 1)
        ]
        for name, w, axis, _ in layers
    }
    sizes = {name: sz for name, _, _, sz in layers}

    # greedy: pop the decrement with the least energy-loss per param removed
    def cost(name: str, n_from: int) -> float:
        d_energy = energy[name][n_from] - energy[name][n_from - 1]
        d_params = sizes[name] / m  # params removed by one N decrement
        return d_energy / max(d_params, 1.0)

    heap = [(cost(nm, m), nm, m) for nm, *_ in layers]
    heapq.heapify(heap)
    while kept > budget and heap:
        _, name, n_from = heapq.heappop(heap)
        if n_cur[name] != n_from or n_from <= min_n:
            continue  # stale entry
        n_cur[name] = n_from - 1
        kept -= sizes[name] / m
        if n_cur[name] > min_n:
            heapq.heappush(heap, (cost(name, n_cur[name]), name, n_cur[name]))

    patterns = [
        (f"^{re.escape(name)}$", NMSparsity(n_cur[name], m, axis))
        for name, _, axis, _ in layers
    ]
    return SparsityConfig(
        default=base.default,
        layer_patterns=tuple(patterns),
        extra_excludes=base.extra_excludes,
        min_dim=base.min_dim,
    )


def assigned_ratios(cfg: SparsityConfig) -> dict[str, str]:
    """Pretty per-layer table of a domino-assigned config."""
    return {regex.strip("^$").replace("\\", ""): str(p) for regex, p in cfg.layer_patterns}
