"""N:M structured-sparsity mask math (pure jnp reference path).

An N:M mask keeps the N largest-magnitude elements out of every contiguous
group of M elements along a chosen axis of a weight tensor. For a matmul
weight stored ``(in_features, out_features)`` (the layout used throughout
``repro.models``: ``y = x @ W``), groups run along the *reduction* axis
(axis 0) so that an N:M-compressed matmul can skip pruned input channels —
the same convention NVIDIA ASP uses for Sparse Tensor Cores, and the one our
``kernels/nm_spmm`` Pallas kernel consumes.

The Pallas-fused version of :func:`nm_mask` lives in ``repro.kernels.nm_mask``;
this module is the oracle (``kernels/ref.py`` re-exports from here) and the
default on CPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NMSparsity:
    """An N:M sparsity pattern: keep ``n`` of every ``m`` consecutive elements.

    ``group_axis`` selects the tensor axis the groups run along (default 0,
    the reduction axis of an ``(in, out)`` matmul weight).
    """

    n: int
    m: int
    group_axis: int = 0

    def __post_init__(self):
        if not (1 <= self.n <= self.m):
            raise ValueError(f"need 1 <= N <= M, got {self.n}:{self.m}")

    @property
    def density(self) -> float:
        return self.n / self.m

    def __str__(self) -> str:  # "2:4"
        return f"{self.n}:{self.m}"


def _move_group_axis_last(w: jnp.ndarray, axis: int) -> jnp.ndarray:
    return jnp.moveaxis(w, axis, -1)


def nm_mask(
    w: jnp.ndarray,
    n: int,
    m: int,
    group_axis: int = 0,
) -> jnp.ndarray:
    """Compute the binary N:M mask of ``w`` by magnitude.

    Returns a mask of ``w.dtype`` with exactly ``n`` ones per group of ``m``
    consecutive elements along ``group_axis``. Ties are broken towards the
    lower index (deterministic), matching ``jax.lax.top_k`` semantics.
    """
    if n == m:
        return jnp.ones_like(w)
    axis = group_axis % w.ndim
    if w.shape[axis] % m != 0:
        raise ValueError(
            f"axis {axis} of shape {w.shape} not divisible by group size {m}"
        )
    wt = _move_group_axis_last(w, axis)
    gshape = wt.shape[:-1] + (wt.shape[-1] // m, m)
    groups = jnp.abs(wt.reshape(gshape))
    # top-n indices per group; scatter ones.
    _, idx = jax.lax.top_k(groups, n)  # (..., G, n)
    mask = jnp.zeros(gshape, dtype=w.dtype)
    mask = jnp.put_along_axis(mask, idx, jnp.ones_like(idx, dtype=w.dtype), axis=-1, inplace=False)
    mask = mask.reshape(wt.shape)
    return jnp.moveaxis(mask, -1, axis)


def nm_mask_and_apply(
    w: jnp.ndarray, n: int, m: int, group_axis: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return ``(mask, mask * w)`` — the fused form the Pallas kernel mirrors."""
    mask = nm_mask(w, n, m, group_axis)
    return mask, mask * w


def nm_compress(
    w: jnp.ndarray, n: int, m: int, group_axis: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compress ``w`` to its N:M representation.

    Returns ``(values, indices)`` where along ``group_axis`` only the kept
    elements remain: ``values`` has size ``dim * n / m`` on that axis, and
    ``indices`` (uint8, same shape as values) holds each kept element's
    offset within its group of ``m``. Indices within a group are sorted
    ascending so decompression is order-stable.
    """
    axis = group_axis % w.ndim
    wt = _move_group_axis_last(w, axis)
    gshape = wt.shape[:-1] + (wt.shape[-1] // m, m)
    groups = wt.reshape(gshape)
    _, idx = jax.lax.top_k(jnp.abs(groups), n)  # (..., G, n)
    idx = jnp.sort(idx, axis=-1)
    vals = jnp.take_along_axis(groups, idx, axis=-1)  # (..., G, n)
    out_shape = wt.shape[:-1] + (gshape[-2] * n,)
    vals = vals.reshape(out_shape)
    idx = idx.astype(jnp.uint8).reshape(out_shape)
    return jnp.moveaxis(vals, -1, axis), jnp.moveaxis(idx, -1, axis)


def nm_decompress(
    values: jnp.ndarray,
    indices: jnp.ndarray,
    n: int,
    m: int,
    group_axis: int = 0,
) -> jnp.ndarray:
    """Scatter an (values, indices) N:M-compressed tensor back to dense."""
    axis = group_axis % values.ndim
    vt = _move_group_axis_last(values, axis)
    it = _move_group_axis_last(indices, axis).astype(jnp.int32)
    g = vt.shape[-1] // n
    vt = vt.reshape(vt.shape[:-1] + (g, n))
    it = it.reshape(it.shape[:-1] + (g, n))
    dense = jnp.zeros(vt.shape[:-1] + (m,), dtype=values.dtype)
    dense = jnp.put_along_axis(dense, it, vt, axis=-1, inplace=False)
    dense = dense.reshape(dense.shape[:-2] + (g * m,))
    return jnp.moveaxis(dense, -1, axis)


def nm_mask_dynamic(
    w: jnp.ndarray,
    n: jnp.ndarray,
    m: int,
    group_axis: int = 0,
) -> jnp.ndarray:
    """N:M mask where N is a *traced* scalar (needed by the Decaying-Mask
    recipe, whose N shrinks over training inside a jitted step).

    Uses rank-within-group (double argsort) instead of ``top_k`` since the
    latter needs a static k: ``mask[i] = rank(|w[i]|) < n``.
    """
    axis = group_axis % w.ndim
    if w.shape[axis] % m != 0:
        raise ValueError(
            f"axis {axis} of shape {w.shape} not divisible by group size {m}"
        )
    wt = _move_group_axis_last(w, axis)
    gshape = wt.shape[:-1] + (wt.shape[-1] // m, m)
    groups = jnp.abs(wt.reshape(gshape))
    order = jnp.argsort(-groups, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1)
    mask = (rank < n).astype(w.dtype).reshape(wt.shape)
    return jnp.moveaxis(mask, -1, axis)


def sparsity_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of zeros in a mask (1 - density)."""
    return 1.0 - jnp.mean(mask.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Straight-Through Estimator primitives (paper Eq. 8 / Eq. 9).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def straight_through_mask(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """``mask * w`` in the forward pass; identity gradient to ``w`` (STE).

    This is Eq. (8) of the paper: the loss is evaluated at ``Π ⊙ w`` but the
    full gradient is applied to the dense ``w`` (d(Π⊙w)/dw ≈ I), which is what
    lets pruned weights regrow and the mask keep evolving.
    """
    return w * mask


def _stm_fwd(w, mask):
    return w * mask, None


def _stm_bwd(_, g):
    return (g, None)


straight_through_mask.defvjp(_stm_fwd, _stm_bwd)


def masked_no_ste(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """``mask * w`` with the *true* gradient ``mask * g`` (no straight-through).

    Used by the ASP recipe, where the mask is fixed and pruned weights must
    stay dead.
    """
    return w * jax.lax.stop_gradient(mask)


def sr_ste_grad_term(
    w: jnp.ndarray, mask: jnp.ndarray, lam: float
) -> jnp.ndarray:
    """The SR-STE regularization term ``λ (1 − Π) ⊙ w`` (paper Eq. 9).

    Added to the STE gradient; decays pruned weights towards zero so the mask
    stabilizes.
    """
    return lam * (1.0 - mask) * w
