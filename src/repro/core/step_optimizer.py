"""The STEP optimizer (paper Algorithm 1): two-phase Adam with preconditioned
variance for learning N:M masks from scratch.

Phase 1 (precondition): plain Adam; the variance ``v`` is updated every step
and AutoSwitch monitors the per-coordinate variance change. No mask is
applied in the forward pass.

Phase 2 (mask learning): the bias-corrected variance at the switch step is
frozen into the preconditioner ``P* = sqrt(v̂_{t0}) + eps`` and never updated
again; only the momentum keeps integrating the (STE) gradients:

    w_{t+1} = w_t - γ_t * m̂_{t+1} / P*            (Algorithm 1, line 20)

The whole state machine is branchless-traced (``jnp.where`` on a phase flag),
so a single jitted train step covers both phases, the switch happens
on-device with no host synchronization, and checkpoints capture the phase
exactly. ``lax.cond`` is used only where the phases differ in *work*
(the mask computation — see recipes.py), not in the optimizer itself, since
the Adam math is elementwise and cheap relative to the model.

Ablation hooks (paper §6):
- ``switch_at``: fixed switching step instead of AutoSwitch (Ablation III).
- ``update_v_in_phase2``: keep updating v during mask learning (Ablation IV —
  the paper shows this *hurts*; we reproduce that).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.autoswitch import (
    AutoSwitchConfig,
    AutoSwitchState,
    autoswitch_step,
    init_autoswitch,
    variance_change_sample,
)
from repro.optim.base import GradientTransformation

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class StepConfig:
    learning_rate: Schedule = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    autoswitch: AutoSwitchConfig = dataclasses.field(
        default_factory=AutoSwitchConfig
    )
    switch_at: Optional[int] = None  # fixed t0 (overrides AutoSwitch)
    update_v_in_phase2: bool = False  # Ablation IV (paper shows: keep False)

    def __post_init__(self):
        # keep the AutoSwitch window consistent with beta2 unless overridden
        if self.autoswitch.beta2 != self.b2:
            object.__setattr__(
                self,
                "autoswitch",
                dataclasses.replace(self.autoswitch, beta2=self.b2),
            )


class StepState(NamedTuple):
    step: jnp.ndarray  # int32: global step t
    m: Any  # first moment
    v: Any  # second moment (live during phase 1; frozen afterwards)
    precond: Any  # P* = sqrt(v̂_{t0}) + eps (ones until the switch)
    phase2: jnp.ndarray  # bool: inside the mask-learning phase?
    t0: jnp.ndarray  # int32: switch step (0 until it happens)
    autoswitch: AutoSwitchState
    z_bar: jnp.ndarray  # last window-mean of the variance change (telemetry)


def _lr(schedule: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    if callable(schedule):
        return schedule(step)
    return jnp.asarray(schedule, jnp.float32)


def step_optimizer(cfg: StepConfig) -> GradientTransformation:
    """Build STEP as a GradientTransformation.

    ``update(grads, state, params)`` expects the gradients already computed
    through the recipe's forward masking (Eq. 8/9 — see recipes.py); the
    optimizer itself only implements the two-phase moment logic.
    """
    asw_cfg = cfg.autoswitch

    def init(params) -> StepState:
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
        ones = jax.tree_util.tree_map(
            lambda p: jnp.ones_like(p, dtype=jnp.float32), params
        )
        return StepState(
            step=jnp.zeros((), jnp.int32),
            m=zeros(),
            v=zeros(),
            precond=ones,
            phase2=jnp.zeros((), jnp.bool_),
            t0=jnp.zeros((), jnp.int32),
            autoswitch=init_autoswitch(asw_cfg),
            z_bar=jnp.asarray(jnp.inf, jnp.float32),
        )

    def update(grads, state: StepState, params=None):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        in_p2 = state.phase2  # phase flag *entering* this step
        b1, b2, eps = cfg.b1, cfg.b2, cfg.eps

        # --- momentum: updated identically in both phases (Alg.1 l.4 & l.18)
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state.m,
            grads,
        )
        bc1 = 1 - b1**tf

        # --- variance: live in phase 1, frozen in phase 2 (unless ablating)
        def v_new_leaf(vv, g):
            nv = b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32))
            if cfg.update_v_in_phase2:
                return nv
            return jnp.where(in_p2, vv, nv)

        v = jax.tree_util.tree_map(v_new_leaf, state.v, grads)
        bc2 = 1 - b2**tf

        # --- AutoSwitch sampling (phase-1 signal; harmless but unused in p2)
        z_t = variance_change_sample(grads, state.v, asw_cfg)
        asw_state, z_bar, crit = autoswitch_step(state.autoswitch, z_t, t, asw_cfg)
        if cfg.switch_at is not None:
            crit = t >= cfg.switch_at
        switch_now = jnp.logical_and(jnp.logical_not(in_p2), crit)
        phase2 = jnp.logical_or(in_p2, crit)
        t0 = jnp.where(switch_now, t, state.t0)

        # --- freeze the preconditioner at the switch step (Alg.1 l.11)
        precond = jax.tree_util.tree_map(
            lambda pc, vv: jnp.where(switch_now, jnp.sqrt(vv / bc2) + eps, pc),
            state.precond,
            v,
        )

        # --- the update direction
        def direction(mm, vv, pc):
            live = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)  # phase-1 Adam
            frozen = (mm / bc1) / pc  # phase-2 preconditioned (Alg.1 l.20)
            if cfg.update_v_in_phase2:
                # Ablation IV: even in phase 2 use the live v̂
                return jnp.where(in_p2, (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), live)
            return jnp.where(in_p2, frozen, live)

        d = jax.tree_util.tree_map(direction, m, v, precond)
        lr = _lr(cfg.learning_rate, t)
        updates = jax.tree_util.tree_map(lambda u: -lr * u, d)

        return updates, StepState(
            step=t,
            m=m,
            v=v,
            precond=precond,
            phase2=phase2,
            t0=t0,
            autoswitch=asw_state,
            z_bar=z_bar,
        )

    return GradientTransformation(init, update)


def phase2_flag(state: StepState) -> jnp.ndarray:
    """The traced bool the recipe layer reads to decide whether to mask."""
    return state.phase2
