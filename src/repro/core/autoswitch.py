"""AutoSwitch (paper Algorithm 2): automatic detection of the switching point
between the precondition phase and the mask-learning phase.

Per step the subroutine samples the per-coordinate variance change

    Option I :  Z_t = d^{-1} ||v_t - v_{t-1}||_1           (arithmetic mean)
    Option II:  Z_t = exp(d^{-1} ||log|v_t - v_{t-1}|||_1)  (geometric mean)

keeps a sliding window of the last ``T_w = floor(1/(1-beta2))`` samples, and
fires once the window mean drops below Adam's own ``eps`` (no new
hyperparameter — the paper's key point). Optional clipping bounds
``[T_min, T_max]`` (default ``[0.1 T, 0.5 T]``, Geweke-style) regularize the
decision under tight training budgets.

Everything here is jit-compatible: the state is a fixed-size ring buffer and
the decision is a traced boolean, so AutoSwitch lives *inside* the train step
with zero host round-trips.

The incremental identity used to avoid storing v_{t-1}:
    v_t - v_{t-1} = (1 - beta2) * (g_{t-1}^2 - v_{t-1})
so Z_t is computed from the gradient and the *pre-update* variance of the
same step, costing one elementwise pass and a reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AutoSwitchConfig:
    beta2: float = 0.999
    eps: float = 1e-8  # threshold = Adam's eps (paper: reuse, don't tune)
    option: str = "I"  # "I" arithmetic | "II" geometric
    window: Optional[int] = None  # override T_w (default floor(1/(1-beta2)))
    t_min: Optional[int] = None  # optional clipping (paper: 0.1 * T)
    t_max: Optional[int] = None  # optional clipping (paper: 0.5 * T)

    @property
    def t_w(self) -> int:
        if self.window is not None:
            return int(self.window)
        # floor((1-beta2)^-1); round first to absorb fp error (1/(1-0.999)
        # is 999.9999... in float64 but the paper's T_w is 1000)
        return max(1, int(round(1.0 / (1.0 - self.beta2), 6)))


class AutoSwitchState(NamedTuple):
    window: jnp.ndarray  # (T_w,) ring buffer of Z_t samples
    count: jnp.ndarray  # int32: number of samples recorded so far


def init_autoswitch(cfg: AutoSwitchConfig) -> AutoSwitchState:
    return AutoSwitchState(
        window=jnp.zeros((cfg.t_w,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def variance_change_sample(
    grads: Any, v: Any, cfg: AutoSwitchConfig, d: Optional[int] = None
) -> jnp.ndarray:
    """Compute Z_t from this step's gradients and the pre-update variance.

    ``|v_{t+1} - v_t| = (1-beta2) |g_t^2 - v_t|`` per coordinate; ``d`` is the
    total coordinate count (computed from the tree if not given).
    """
    leaves_g = jax.tree_util.tree_leaves(grads)
    leaves_v = jax.tree_util.tree_leaves(v)
    if d is None:
        d = sum(x.size for x in leaves_v)
    d = float(d)  # param counts exceed int32 on multi-B models
    c = 1.0 - cfg.beta2
    if cfg.option == "I":
        tot = sum(
            jnp.sum(jnp.abs(jnp.square(g.astype(jnp.float32)) - vv))
            for g, vv in zip(leaves_g, leaves_v)
        )
        return c * tot / d
    elif cfg.option == "II":
        tiny = 1e-30
        tot = sum(
            jnp.sum(jnp.log(c * jnp.abs(jnp.square(g.astype(jnp.float32)) - vv) + tiny))
            for g, vv in zip(leaves_g, leaves_v)
        )
        return jnp.exp(tot / d)
    raise ValueError(f"unknown AutoSwitch option {cfg.option!r}")


def autoswitch_step(
    state: AutoSwitchState,
    z_t: jnp.ndarray,
    t: jnp.ndarray,
    cfg: AutoSwitchConfig,
) -> tuple[AutoSwitchState, jnp.ndarray, jnp.ndarray]:
    """Record one sample; return (new_state, z_bar, switch_now).

    ``switch_now`` is a traced bool implementing Algorithm 2's return value,
    including the optional clipping branch.
    """
    idx = state.count % cfg.t_w
    window = state.window.at[idx].set(z_t.astype(jnp.float32))
    count = state.count + 1
    z_bar = jnp.sum(window) / cfg.t_w
    ready = count >= cfg.t_w
    crit = ready & (z_bar < cfg.eps)
    if cfg.t_min is not None:
        crit = crit & (t > cfg.t_min)
    if cfg.t_max is not None:
        crit = crit | (t > cfg.t_max)
    return AutoSwitchState(window=window, count=count), z_bar, crit


# ---------------------------------------------------------------------------
# Baseline switching criteria (paper Eq. 10 / Eq. 11) — used by the Table 1
# benchmark. They operate on recorded norm traces (offline), exactly as the
# paper profiles them.
# ---------------------------------------------------------------------------


def criterion_relative_norm(v_norms: jnp.ndarray, threshold: float = 0.5) -> int:
    """Agarwal et al. Eq. (10): first t with |‖v_t‖-‖v_{t-1}‖| / ‖v_{t-1}‖ < thr.

    ``v_norms``: trace of ‖v_t‖₂ per step. Returns the step index (python int),
    or ``len(trace)-1`` if never met.
    """
    v = jnp.asarray(v_norms)
    rel = jnp.abs(v[1:] - v[:-1]) / jnp.maximum(v[:-1], 1e-30)
    hits = jnp.nonzero(rel < threshold, size=1, fill_value=rel.shape[0] - 1)[0]
    return int(hits[0]) + 1


def criterion_staleness(
    v_l1_norms: jnp.ndarray, beta2: float = 0.999, threshold: float = 0.96
) -> int:
    """Tang et al. Eq. (11): first t with ‖v_t‖₁ / ‖v_{t-k}‖₁ > thr,
    k = floor(1/(1-beta2))."""
    v = jnp.asarray(v_l1_norms)
    k = max(1, int(1.0 / (1.0 - beta2)))
    if v.shape[0] <= k:
        return v.shape[0] - 1
    ratio = v[k:] / jnp.maximum(v[:-k], 1e-30)
    hits = jnp.nonzero(ratio > threshold, size=1, fill_value=ratio.shape[0] - 1)[0]
    return int(hits[0]) + k


def criterion_autoswitch_offline(
    z_trace: jnp.ndarray, cfg: AutoSwitchConfig
) -> int:
    """Run Algorithm 2 over a recorded Z_t trace (for the Table 1 benchmark)."""
    z = jnp.asarray(z_trace, jnp.float32)
    t_w = cfg.t_w
    if z.shape[0] < t_w:
        return z.shape[0] - 1
    # sliding-window means
    csum = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(z)])
    zbar = (csum[t_w:] - csum[:-t_w]) / t_w  # mean ending at step t_w-1+i
    ok = zbar < cfg.eps
    t_idx = jnp.arange(t_w - 1, z.shape[0])
    if cfg.t_min is not None:
        ok = ok & (t_idx > cfg.t_min)
    crossed = ok
    if cfg.t_max is not None:
        crossed = crossed | (t_idx > cfg.t_max)
    hits = jnp.nonzero(crossed, size=1, fill_value=crossed.shape[0] - 1)[0]
    return int(t_idx[hits[0]])
