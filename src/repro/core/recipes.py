"""Sparsity training recipes: dense / STE / SR-STE / ASP / Decaying-Mask / STEP.

A recipe decides (a) which weights are fed into the model's forward pass at
each step (masked or not, straight-through or not) and (b) how the raw
gradients are post-processed (SR-STE's decay term). The optimizer is chosen
independently (Adam, momentum SGD, or the STEP two-phase optimizer), matching
the paper's framing where SR-STE×SGD works but SR-STE×Adam fails and
STEP = STE recipe + preconditioned Adam fixes it.

All recipe logic is jit-traceable: phase switches are traced booleans, the
Decaying-Mask N-schedule is a traced integer, and the ASP one-shot prune is a
``jnp.where`` latch. ``lax.cond`` guards the mask computation so the
precondition phase pays nothing for masks it does not use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.masking import NMSparsity
from repro.core.sparsity_config import SparsityConfig, maskable_map
from repro.utils.tree import tree_map_with_name, tree_paths

RECIPES = ("dense", "ste", "sr_ste", "asp", "decay", "step", "step_sr")


class RecipeState(NamedTuple):
    """Traced per-recipe state carried in the train state."""

    step: jnp.ndarray  # int32 (recipes keep their own count: robust to resume)
    fixed_mask: Any  # ASP's one-shot mask (ones until pruned); () otherwise
    pruned: jnp.ndarray  # bool: ASP latch


@dataclasses.dataclass(frozen=True)
class Recipe:
    """A sparsity training recipe bound to a SparsityConfig.

    kind:
      dense    — no masking ever (paper's "Dense" row).
      ste      — mask every step, straight-through gradients (Eq. 8).
      sr_ste   — ste + λ(1−Π)⊙w gradient decay (Eq. 9, Zhou et al.).
      asp      — dense until ``prune_at``; then one-shot magnitude mask,
                 frozen, with true masked gradients (Mishra et al.).
      decay    — dense until ``dense_until``; then STE with N decaying
                 (M-1) → M/2 → M/4 → … → target N every ``decay_interval``
                 steps (Kao et al.).
      step     — mask only in the optimizer's phase 2 (Algorithm 1); pairs
                 with ``core.step_optimizer``. Plain STE in phase 2.
      step_sr  — STEP whose phase-2 gradients also carry the SR-STE term.
    """

    kind: str = "step"
    sparsity: SparsityConfig = dataclasses.field(default_factory=SparsityConfig)
    sr_lambda: float = 2e-4  # SR-STE λ (paper uses SR-STE's tuned value)
    prune_at: int = 0  # ASP: one-shot prune step
    dense_until: int = 0  # decay: length of dense warmup
    decay_interval: int = 100  # decay: steps between N reductions

    def __post_init__(self):
        if self.kind not in RECIPES:
            raise ValueError(f"unknown recipe {self.kind!r}; choose from {RECIPES}")

    # -- state ---------------------------------------------------------------

    def init_state(self, params: Any) -> RecipeState:
        if self.kind == "asp":
            fixed = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), params)
        else:
            fixed = ()
        return RecipeState(
            step=jnp.zeros((), jnp.int32),
            fixed_mask=fixed,
            pruned=jnp.zeros((), jnp.bool_),
        )

    # -- masks ---------------------------------------------------------------

    def _mask_tree(self, params: Any, n_override: Optional[jnp.ndarray] = None) -> Any:
        """Compute the N:M mask for every maskable leaf (ones elsewhere)."""

        def leaf(name, p):
            pat = self.sparsity.pattern_for(name, tuple(p.shape))
            if pat is None:
                return jnp.ones_like(p)
            if n_override is not None:
                n_eff = jnp.minimum(
                    jnp.maximum(n_override, pat.n), pat.m
                )  # decay floor = target N
                return masking.nm_mask_dynamic(p, n_eff, pat.m, pat.group_axis)
            return masking.nm_mask(p, pat.n, pat.m, pat.group_axis)

        return tree_map_with_name(leaf, params)

    def _ones_tree(self, params: Any) -> Any:
        return jax.tree_util.tree_map(lambda p: jnp.ones_like(p), params)

    def _decay_n(self, t: jnp.ndarray, m: int) -> jnp.ndarray:
        """Kao et al. decaying schedule: N_i = M-1, then ⌊M/2^i⌋, floored at
        the target N (applied per-leaf via n_override clamping)."""
        i = jnp.maximum(0, (t - self.dense_until) // self.decay_interval)
        n_pow = jnp.maximum(1, m // (2**jnp.minimum(i, 30)))
        return jnp.where(i == 0, m - 1, n_pow).astype(jnp.int32)

    # -- the recipe's step-level API ------------------------------------------

    def masks_for_step(
        self, params: Any, state: RecipeState, phase2: jnp.ndarray
    ) -> tuple[Any, jnp.ndarray, RecipeState]:
        """Return (mask_tree, active, new_state) for this step.

        ``active`` is a traced bool: whether masking applies this step.
        ``phase2`` is the STEP optimizer's phase flag (ignored by other
        recipes).
        """
        t = state.step
        kind = self.kind

        if kind == "dense":
            return self._ones_tree(params), jnp.zeros((), jnp.bool_), state._replace(step=t + 1)

        if kind in ("ste", "sr_ste"):
            return self._mask_tree(params), jnp.ones((), jnp.bool_), state._replace(step=t + 1)

        if kind in ("step", "step_sr"):
            active = phase2
            mask = jax.lax.cond(
                active,
                lambda p: self._mask_tree(p),
                lambda p: self._ones_tree(p),
                params,
            )
            return mask, active, state._replace(step=t + 1)

        if kind == "decay":
            active = t >= self.dense_until
            # max M across leaves bounds the schedule; per-leaf clamp handles
            # heterogeneous (n, m) patterns.
            pats = [
                self.sparsity.pattern_for(name, tuple(p.shape))
                for name, p in zip(
                    tree_paths(params), jax.tree_util.tree_leaves(params)
                )
            ]
            m_global = max([p.m for p in pats if p is not None] or [4])
            n_t = self._decay_n(t, m_global)
            mask = jax.lax.cond(
                active,
                lambda p: self._mask_tree(p, n_override=n_t),
                lambda p: self._ones_tree(p),
                params,
            )
            return mask, active, state._replace(step=t + 1)

        if kind == "asp":
            prune_now = jnp.logical_and(
                jnp.logical_not(state.pruned), t >= self.prune_at
            )
            new_mask_tree = jax.lax.cond(
                prune_now,
                lambda p: self._mask_tree(p),
                lambda p: state.fixed_mask,
                params,
            )
            fixed = jax.tree_util.tree_map(
                lambda old, new: jnp.where(prune_now, new, old),
                state.fixed_mask,
                new_mask_tree,
            )
            pruned = jnp.logical_or(state.pruned, prune_now)
            new_state = RecipeState(step=t + 1, fixed_mask=fixed, pruned=pruned)
            return fixed, pruned, new_state

        raise AssertionError(kind)

    def forward_params(self, params: Any, mask: Any, active: jnp.ndarray) -> Any:
        """The weights fed to the model this step (Eq. 8's Π⊙w, via STE)."""
        if self.kind == "dense":
            return params
        if self.kind == "asp":
            # true masked gradient: pruned weights stay dead
            return jax.tree_util.tree_map(
                lambda p, mk: masking.masked_no_ste(
                    p, jnp.where(active, mk, jnp.ones_like(mk))
                ),
                params,
                mask,
            )
        # STE family: straight-through — full gradient reaches dense weights
        return jax.tree_util.tree_map(
            lambda p, mk: masking.straight_through_mask(
                p, jnp.where(active, mk, jnp.ones_like(mk))
            ),
            params,
            mask,
        )

    def grad_postprocess(
        self, grads: Any, params: Any, mask: Any, active: jnp.ndarray
    ) -> Any:
        """Add the SR-STE λ(1−Π)⊙w term where applicable (Eq. 9)."""
        if self.kind not in ("sr_ste", "step_sr"):
            return grads
        lam = self.sr_lambda

        def leaf(g, p, mk):
            term = masking.sr_ste_grad_term(p.astype(jnp.float32), mk, lam)
            return g + jnp.where(active, term, 0.0).astype(g.dtype)

        return jax.tree_util.tree_map(leaf, grads, params, mask)

    # -- export ---------------------------------------------------------------

    def final_masks(self, params: Any) -> Any:
        """Π_T for inference (Algorithm 1, line 23)."""
        if self.kind == "dense":
            return self._ones_tree(params)
        return self._mask_tree(params)

    def export_sparse(self, params: Any) -> Any:
        """Π_T ⊙ w_T — the deployable sparse model (Algorithm 1, line 24)."""
        masks = self.final_masks(params)
        return jax.tree_util.tree_map(lambda p, mk: p * mk, params, masks)


def make_recipe(kind: str, sparsity: Optional[SparsityConfig] = None, **kw) -> Recipe:
    return Recipe(kind=kind, sparsity=sparsity or SparsityConfig(), **kw)
