"""Which parameters get N:M-masked, and with what pattern.

Implements the paper's masking scope ("all Linear/Conv modules") generalized
to the framework's model zoo: a leaf is maskable iff it is a >=2-D matmul
weight with every grouped dim >= M, excluding embeddings/unembedding, norms,
biases, MoE routers and diagonal/recurrence parameters (see DESIGN.md §4).

Per-layer mixed ratios (DominoSearch-style, paper Table 4) are expressed by
``layer_patterns``: a list of (regex, NMSparsity) tried in order; first match
wins; non-matching maskable leaves use ``default``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.masking import NMSparsity
from repro.utils.tree import tree_map_with_name

# name fragments that are never masked, whatever their shape
_EXCLUDE_FRAGMENTS = (
    "embed",      # token / position / codebook embeddings (+ unembed)
    "norm",       # layer/rms norms
    "bias",
    "router",     # MoE gate — tiny and accuracy-critical
    "scale",
    "a_log",      # mamba2 / rg-lru recurrence parameters
    "d_skip",     # mamba2 per-head D skip (1-D; 2-D only when scan-stacked)
    "dt_",        # mamba2 dt projection bias & init
    "conv",       # mamba2 short conv (depthwise, tiny)
    "gate_diag",  # rg-lru diagonal gates
    "lambda",
)


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Global sparsity policy for a parameter tree."""

    default: NMSparsity = NMSparsity(2, 4)
    layer_patterns: Sequence[tuple[str, NMSparsity]] = ()
    extra_excludes: Sequence[str] = ()
    min_dim: Optional[int] = None  # both dims must be >= this (default: M)

    def pattern_for(self, name: str, shape: tuple[int, ...]) -> Optional[NMSparsity]:
        """The N:M pattern for a named leaf, or None if it must stay dense."""
        lname = name.lower()
        for frag in _EXCLUDE_FRAGMENTS:
            if frag in lname:
                return None
        for frag in self.extra_excludes:
            if frag in lname:
                return None
        if len(shape) < 2:
            return None
        pat = self.default
        for regex, p in self.layer_patterns:
            if re.search(regex, name):
                pat = p
                break
        if pat is None:
            return None
        # Matmul weights are laid out (..., in, out) everywhere in the zoo
        # (scan-stacked: (L, in, out); MoE experts: (E, in, out)), and N:M
        # groups must run along the *contraction* dim = axis -2. A configured
        # group_axis of 0 means "the reduction axis" and is normalized to -2,
        # which is identical for plain 2-D weights but correct for stacked
        # leaves (masking along the layer/expert axis would be meaningless).
        ga = -2 if pat.group_axis == 0 else pat.group_axis
        if pat.group_axis != ga:
            pat = dataclasses.replace(pat, group_axis=ga)
        axis = pat.group_axis % len(shape)
        if shape[axis] % pat.m != 0:
            return None  # group dim not divisible: stay dense (recorded)
        floor = self.min_dim if self.min_dim is not None else pat.m
        if min(shape[-2:]) < floor:
            return None
        return pat


def maskable_map(params: Any, cfg: SparsityConfig) -> Any:
    """Tree of Optional[NMSparsity], aligned with ``params``."""
    return tree_map_with_name(
        lambda name, p: cfg.pattern_for(name, tuple(p.shape)), params
    )


def sparsity_report(params: Any, cfg: SparsityConfig) -> dict:
    """Human-readable coverage summary (used in EXPERIMENTS.md §Arch tables)."""
    total = 0
    masked = 0
    removed = 0.0
    per_leaf = {}
    leaves = jax.tree_util.tree_leaves_with_path(params)
    from repro.utils.tree import _path_str

    for path, p in leaves:
        name = _path_str(path)
        pat = cfg.pattern_for(name, tuple(p.shape))
        total += p.size
        if pat is not None:
            masked += p.size
            removed += p.size * (1 - pat.density)
            per_leaf[name] = str(pat)
        else:
            per_leaf[name] = "dense"
    return {
        "total_params": total,
        "maskable_params": masked,
        "maskable_fraction": masked / max(total, 1),
        "removed_fraction_of_total": removed / max(total, 1),
        "per_leaf": per_leaf,
    }
