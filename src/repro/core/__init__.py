# The paper's primary contribution: N:M structured-sparsity mask learning
# with preconditioned Adam (STEP) + the AutoSwitch phase detector, plus all
# baseline recipes the paper compares against.
from repro.core.masking import (
    NMSparsity,
    nm_mask,
    nm_mask_dynamic,
    nm_mask_and_apply,
    nm_compress,
    nm_decompress,
    straight_through_mask,
    masked_no_ste,
    sr_ste_grad_term,
    sparsity_fraction,
)
from repro.core.sparsity_config import SparsityConfig, maskable_map, sparsity_report
from repro.core.autoswitch import (
    AutoSwitchConfig,
    AutoSwitchState,
    init_autoswitch,
    autoswitch_step,
    variance_change_sample,
    criterion_relative_norm,
    criterion_staleness,
    criterion_autoswitch_offline,
)
from repro.core.step_optimizer import StepConfig, StepState, step_optimizer
from repro.core.recipes import Recipe, RecipeState, make_recipe, RECIPES
from repro.core.domino import domino_search, assigned_ratios
