"""Adam / AdamW / momentum-SGD as GradientTransformations.

These are the *plain* optimizers (paper Eq. 2-7). The STEP two-phase variant —
the paper's contribution — lives in ``repro.core.step_optimizer`` and reuses
the same state layout so checkpoints are interchangeable between dense Adam
(precondition phase) and STEP.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr(schedule: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    if callable(schedule):
        return schedule(step)
    return jnp.asarray(schedule, jnp.float32)


class AdamState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    m: Any  # first moment
    v: Any  # second moment ("variance" in the paper)


def scale_by_adam(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
) -> GradientTransformation:
    """Adam moment update + bias correction, producing the *direction* m̂/(√v̂+ε)."""

    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
        return AdamState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())

    def update(grads, state, params=None):
        step = state.step + 1
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state.m, grads
        )
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.v,
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda mm, vv: (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), m, v
        )
        return updates, AdamState(step=step, m=m, v=v)

    return GradientTransformation(init, update)


def adam(
    learning_rate: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> GradientTransformation:
    inner = scale_by_adam(b1, b2, eps)

    def init(params):
        return inner.init(params)

    def update(grads, state, params=None):
        updates, new_state = inner.update(grads, state, params)
        lr = _lr(learning_rate, new_state.step)
        updates = jax.tree_util.tree_map(lambda u: -lr * u, updates)
        return updates, new_state

    return GradientTransformation(init, update)


def adamw(
    learning_rate: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Optional[Callable[[Any], Any]] = None,
) -> GradientTransformation:
    """Adam with decoupled weight decay. ``mask(params)`` returns a tree of
    bools selecting which leaves are decayed (default: all)."""
    inner = scale_by_adam(b1, b2, eps)

    def init(params):
        return inner.init(params)

    def update(grads, state, params=None):
        updates, new_state = inner.update(grads, state, params)
        lr = _lr(learning_rate, new_state.step)
        if weight_decay and params is not None:
            decay_sel = (
                mask(params)
                if mask is not None
                else jax.tree_util.tree_map(lambda _: True, params)
            )
            updates = jax.tree_util.tree_map(
                lambda u, p, d: u + (weight_decay * p.astype(jnp.float32) if d else 0.0),
                updates,
                params,
                decay_sel,
            )
        updates = jax.tree_util.tree_map(lambda u: -lr * u, updates)
        return updates, new_state

    return GradientTransformation(init, update)


class SgdState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd(
    learning_rate: Schedule, momentum: float = 0.9, nesterov: bool = False
) -> GradientTransformation:
    """Momentum SGD — the optimizer SR-STE was originally tuned for."""

    def init(params):
        return SgdState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
            ),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        buf = jax.tree_util.tree_map(
            lambda b, g: momentum * b + g.astype(jnp.float32), state.momentum, grads
        )
        d = (
            jax.tree_util.tree_map(
                lambda g, b: g.astype(jnp.float32) + momentum * b, grads, buf
            )
            if nesterov
            else buf
        )
        lr = _lr(learning_rate, step)
        updates = jax.tree_util.tree_map(lambda u: -lr * u, d)
        return updates, SgdState(step=step, momentum=buf)

    return GradientTransformation(init, update)
