"""Minimal gradient-transformation framework (optax-style, self-contained).

The container has no optax; the framework builds its own composable optimizer
stack. A :class:`GradientTransformation` is an ``(init, update)`` pair:

    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

Updates are *added* to params (sign convention: the transformation itself
negates by the learning rate).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm

PyTree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], Any]
    update: Callable[..., tuple[PyTree, Any]]


def identity() -> GradientTransformation:
    return GradientTransformation(
        init=lambda params: (),
        update=lambda grads, state, params=None: (grads, state),
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ClipState()

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    return GradientTransformation(
        init=lambda params: (),
        update=lambda g, s, p=None: (
            jax.tree_util.tree_map(lambda x: x * factor, g),
            s,
        ),
    )
