"""Learning-rate schedules (functions of the integer step)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup_cosine_decay(
    peak: float,
    warmup_steps: int,
    total_steps: int,
    end_factor: float = 0.1,
) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps)
        frac = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = end_factor * peak + (1 - end_factor) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def linear_decay(peak: float, total_steps: int, warmup_steps: int = 0) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup_steps) if warmup_steps else peak
        frac = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        dec = peak * jnp.clip(1.0 - frac, 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, dec)

    return fn


def inverse_sqrt_schedule(peak: float, warmup_steps: int) -> Schedule:
    """The "Attention is All You Need" schedule (used for the WMT analogue)."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        return peak * jnp.minimum(
            step / jnp.maximum(1.0, warmup_steps) ** 1.5, step**-0.5
        ) * jnp.sqrt(jnp.maximum(1.0, warmup_steps))

    return fn
