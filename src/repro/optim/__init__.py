from repro.optim.base import GradientTransformation, chain, identity, clip_by_global_norm
from repro.optim.adam import adam, adamw, sgd, scale_by_adam
from repro.optim.schedules import (
    constant_schedule,
    linear_warmup_cosine_decay,
    linear_decay,
    inverse_sqrt_schedule,
)
from repro.optim.compression import ef_sign_compress, CompressionState
