"""Error-feedback sign compression for data-parallel gradient all-reduce.

A beyond-paper distributed-optimization trick that *depends on* the paper's
core insight: 1-bit Adam (Tang et al., 2021 — cited by STEP as its
motivation) shows compressed gradient communication only works for Adam once
the variance is frozen. STEP's mask-learning phase freezes ``v*`` by
construction, so during phase 2 the DP all-reduce can switch to 1-bit
sign compression with error feedback — cutting cross-pod gradient traffic
16x (bf16 -> 1 bit + one f32 scale per tensor) exactly when most of the
training run happens.

Usage inside a shard_map'd train step::

    compressed, state = ef_compress_decompress(grad, state)
    grad = jax.lax.pmean(compressed, axis_name)     # tiny payload semantics

On real hardware the payload is packed to int8 words by XLA; in this
framework the roofline accounting (benchmarks/roofline.py) models the 1-bit
wire format analytically while the numerics below are exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    """Per-leaf error-feedback residual (same tree structure as grads)."""

    residual: Any


def init_compression_state(grads_like: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads_like
        )
    )


def _compress_leaf(g: jnp.ndarray, r: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """1-bit sign compression with L1-scale, returning (compressed, new_residual).

    compressed = sign(x) * mean|x| where x = g + residual; the quantization
    error is carried to the next step (error feedback), which is what makes
    the scheme convergent (Tang et al., 2021).
    """
    x = g.astype(jnp.float32) + r
    scale = jnp.mean(jnp.abs(x))
    q = jnp.sign(x) * scale
    return q, x - q


def ef_sign_compress(
    grads: Any, state: CompressionState, enabled
) -> tuple[Any, CompressionState]:
    """Compress a gradient tree with error feedback.

    ``enabled`` is a traced boolean — when False (precondition phase) the
    gradients pass through untouched and the residual stays zero, so the
    compressor can live inside a single jitted train step and switch on at
    the STEP phase boundary without recompilation.
    """

    def leaf(g, r):
        q, new_r = _compress_leaf(g, r)
        gq = jnp.where(enabled, q, g.astype(jnp.float32))
        nr = jnp.where(enabled, new_r, r)
        return gq, nr

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    return new_g, CompressionState(residual=new_r)


def compressed_bits_per_element(dtype=jnp.bfloat16) -> float:
    """Wire-format cost model used by the roofline accounting."""
    return 1.0  # 1 bit/elem + negligible per-tensor f32 scale
