"""Fault-tolerant checkpointing.

Guarantees:

- **Atomicity** — checkpoints are written to a temp directory and ``rename``d
  into place; a crash mid-save never corrupts the latest checkpoint.
- **Integrity** — every checkpoint carries a manifest with per-array
  checksums; ``latest_step`` skips checkpoints that fail verification, so a
  torn/partial save degrades to "resume from the previous one".
- **Elasticity** — arrays are stored *unsharded-logical* (full per-tensor
  values). ``load`` takes an optional ``shardings`` tree and device_puts each
  tensor onto whatever mesh the relaunch provides — a 512-chip job can
  restart on 256 chips (or 1 CPU in tests).
- **Retention** — keep-last-k plus optional keep-every-n archival.

Format: one ``.npz`` per checkpoint (fast on a single host; on a real
multi-host cluster the same layout maps to per-host array-shard files — the
manifest/atomic-rename/rehydrate logic is host-count agnostic).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_paths

_SENTINELS = {
    "__none__": None,
}


def _flatten_named(tree: Any) -> dict[str, np.ndarray]:
    names = tree_paths(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    out = {}
    for n, x in zip(names, leaves):
        arr = np.asarray(jax.device_get(x))
        if arr.dtype == jnp.bfloat16:
            out[n + "::bf16"] = arr.view(np.uint16)
        else:
            out[n] = arr
    return out


def save_pytree(path: str, tree: Any, extra_meta: Optional[dict] = None) -> None:
    """Atomic save of a pytree (structure + arrays + manifest) to ``path``/."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_named(tree)
    treedef = jax.tree_util.tree_structure(tree)
    tmpdir = tempfile.mkdtemp(dir=os.path.dirname(path) or ".")
    try:
        npz = os.path.join(tmpdir, "arrays.npz")
        np.savez(npz, **flat)
        checksums = {}
        for k, v in flat.items():
            checksums[k] = hashlib.md5(np.ascontiguousarray(v).tobytes()).hexdigest()
        manifest = {
            "treedef": str(treedef),
            "keys": sorted(flat),
            "checksums": checksums,
            "meta": extra_meta or {},
        }
        with open(os.path.join(tmpdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmpdir, path)  # atomic publish
    except BaseException:
        shutil.rmtree(tmpdir, ignore_errors=True)
        raise


def verify(path: str) -> bool:
    """Checksum-verify a checkpoint directory."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            if sorted(z.files) != manifest["keys"]:
                return False
            for k in z.files:
                h = hashlib.md5(np.ascontiguousarray(z[k]).tobytes()).hexdigest()
                if h != manifest["checksums"][k]:
                    return False
        return True
    except Exception:
        return False


def load_pytree(
    path: str, like: Any, shardings: Any = None
) -> tuple[Any, dict]:
    """Load arrays into the structure of ``like``.

    ``shardings``: optional pytree of jax.sharding.Sharding (or a single
    sharding) — tensors are device_put onto it (elastic re-mesh restore).
    Returns (tree, meta).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = tree_paths(like)
    leaves = jax.tree_util.tree_leaves(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings)
        if shardings is not None and not _is_single_sharding(shardings)
        else [shardings] * len(leaves)
    )
    if len(shard_leaves) != len(leaves):
        shard_leaves = [None] * len(leaves)
    out = []
    with np.load(os.path.join(path, "arrays.npz")) as z:
        for n, ref, sh in zip(names, leaves, shard_leaves):
            if n + "::bf16" in z.files:
                arr = z[n + "::bf16"].view(jnp.bfloat16)
            else:
                arr = z[n]
            x = jnp.asarray(arr)
            if hasattr(ref, "dtype"):
                x = x.astype(ref.dtype)
            if sh is not None:
                x = jax.device_put(x, sh)
            out.append(x)
    return jax.tree_util.tree_unflatten(treedef, out), manifest.get("meta", {})


def _is_single_sharding(s: Any) -> bool:
    from jax.sharding import Sharding

    return isinstance(s, Sharding)


@dataclasses.dataclass
class Checkpointer:
    """Directory-of-checkpoints manager: ``<root>/step_<N>/``."""

    root: str
    keep_last: int = 3
    keep_every: Optional[int] = None  # archive multiples of this step count

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self, verified: bool = True) -> Optional[int]:
        for s in reversed(self.steps()):
            if not verified or verify(self._step_dir(s)):
                return s
        return None

    def save(self, step: int, tree: Any, meta: Optional[dict] = None) -> str:
        path = self._step_dir(step)
        save_pytree(path, tree, {"step": step, **(meta or {})})
        self._gc()
        return path

    def load(
        self, like: Any, step: Optional[int] = None, shardings: Any = None
    ) -> tuple[Any, dict]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.root}")
        return load_pytree(self._step_dir(step), like, shardings)

    def restore_latest(
        self, skeleton: Any, shardings: Any = None
    ) -> Optional[tuple[Any, dict, int]]:
        """Load the newest verified checkpoint into ``skeleton``'s structure.

        Returns ``(tree, meta, step)``, or ``None`` when the directory holds
        no valid checkpoint — the caller keeps its freshly initialized state.
        ``skeleton`` may be a *subtree* of what was saved (leaves are matched
        by name), e.g. ``{"params": params}`` reads just the parameters out
        of a full-TrainState checkpoint.
        """
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = load_pytree(self._step_dir(step), skeleton, shardings)
        return tree, meta, step

    def _gc(self) -> None:
        steps = self.steps()
        keep = set(steps[-self.keep_last :])
        if self.keep_every:
            keep |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
