from repro.checkpoint.checkpointer import Checkpointer, save_pytree, load_pytree
