"""Sharding rules: parameter names -> PartitionSpecs.

Strategy (DESIGN.md §5):

- **TP (Megatron-style)** on the ``model`` axis: QKV/gate/up/in-proj
  column-sharded, O/down/out-proj row-sharded, embeddings vocab-sharded,
  MoE experts expert-sharded (EP == ``model``).
- **FSDP** on the ``data`` axis over the *other* major dim of every big
  matmul weight (ZeRO-3-style); optimizer moments inherit the param spec, so
  optimizer state is fully sharded over all chips.
- **DP** over (``pod``, ``data``) for the batch; gradient reduction becomes
  hierarchical (reduce-scatter intra-pod first — 15/16 of the traffic never
  crosses the DCI).
- **SP**: the residual stream is sequence-sharded on ``model`` at layer
  boundaries via the model's ``block_constraint`` hook, bounding remat-saved
  activations for the 80-layer dry-runs.

Rules key off leaf *names* (the '/'-joined paths from utils.tree); stacked
scan-body leaves ("body/...") get the same spec with a leading ``None`` for
the layer axis.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_map_with_name

MODEL_AXIS = "model"
DP_AXES = ("pod", "data")  # pod omitted automatically on single-pod meshes


def _dp(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


# (regex over the leaf name, spec builder over (ndim, mesh)) — first match
# wins. Specs are written for the *unstacked* rank; a leading None is
# prepended for "body/" leaves.
def _rules(fsdp: bool):
    d = "data" if fsdp else None
    return [
        # embeddings: vocab on model, feature on data (fsdp)
        (r"tok_embed$", lambda: P(MODEL_AXIS, d)),
        (r"out_embed$", lambda: P(d, MODEL_AXIS)),
        (r"frontend_proj$", lambda: P(None, d)),
        # attention
        (r"attn/w(q|k|v)$", lambda: P(d, MODEL_AXIS)),
        (r"attn/wo$", lambda: P(MODEL_AXIS, d)),
        (r"attn/bias_(q|k|v)$", lambda: P(MODEL_AXIS)),
        (r"attn/bias_o$", lambda: P(None)),
        # MLA
        (r"attn/w_q$", lambda: P(d, MODEL_AXIS)),
        (r"attn/w_dkv$", lambda: P(d, None)),
        (r"attn/w_ukv$", lambda: P(d, MODEL_AXIS)),
        (r"attn/w_o$", lambda: P(MODEL_AXIS, d)),
        # dense MLPs (block + MoE shared expert)
        (r"(w_gate|w_up|w_fc)$", lambda: P(d, MODEL_AXIS)),
        (r"(w_down|w_proj)$", lambda: P(MODEL_AXIS, d)),
        (r"b_fc$", lambda: P(MODEL_AXIS)),
        (r"b_proj$", lambda: P(None)),
        # MoE experts: EP on model, fsdp on d_ff
        (r"moe/w_(gate|up)_e$", lambda: P(MODEL_AXIS, None, d)),
        (r"moe/w_down_e$", lambda: P(MODEL_AXIS, d, None)),
        (r"moe/router$", lambda: P(None, None)),
        # Mamba-2
        (r"mixer/w_in$", lambda: P(d, MODEL_AXIS)),
        (r"mixer/w_out$", lambda: P(MODEL_AXIS, d)),
        (r"mixer/conv_w$", lambda: P(None, MODEL_AXIS)),
        # RG-LRU
        (r"mixer/w_(x|gate_branch|a_gate|i_gate)$", lambda: P(d, MODEL_AXIS)),
        # norms / scalars / small vectors: replicated
        (r".*", lambda: P()),
    ]


def param_pspec(
    name: str, ndim: int, *, fsdp: bool = True
) -> P:
    stacked = re.search(r"(^|/)body/", name) is not None
    base_ndim = ndim - 1 if stacked else ndim
    for regex, build in _rules(fsdp):
        if re.search(regex, name):
            spec = build()
            break
    spec_t = tuple(spec) + (None,) * (base_ndim - len(tuple(spec)))
    spec_t = spec_t[:base_ndim]
    if stacked:
        spec_t = (None,) + spec_t
    return P(*spec_t)


def tree_param_pspecs(params_like: Any, *, fsdp: bool = True) -> Any:
    """PartitionSpec tree aligned with a (possibly abstract) param tree."""
    return tree_map_with_name(
        lambda name, x: param_pspec(name, len(x.shape), fsdp=fsdp), params_like
    )


def tree_param_shardings(mesh: Mesh, params_like: Any, *, fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        tree_param_pspecs(params_like, fsdp=fsdp),
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_pspecs(mesh: Mesh, batch_like: Any) -> Any:
    """Batch arrays: leading dim over DP axes, rest replicated."""
    dp = _dp(mesh)

    def spec(x):
        return P(dp, *([None] * (len(x.shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_like)


def cache_pspecs(mesh: Mesh, cache_like: Any, *, kv_shard: str = "seq") -> Any:
    """Decode caches: batch over DP; one more axis over ``model``.

    ``kv_shard`` picks the model-axis dim for K/V-style (B, S, ...) caches:

    - ``"seq"`` (default): shard the *sequence* axis. Decode attention then
      computes local partial scores/softmax stats and psums tiny reductions —
      context-parallel decode. Measured 75x less collective traffic than
      head/feature sharding (§Perf hillclimb #3: GSPMD's resharding of
      hd-sharded caches triggers involuntary full-cache all-gathers).
    - ``"feature"``: shard the trailing dim (hd / kv_lora) — the baseline
      layout kept for the §Perf before/after comparison.

    SSM states (B, H, P, N) shard H on model either way.
    """
    dp = _dp(mesh)

    def leaf(name: str, x):
        nd = len(x.shape)
        if name.endswith("len") or nd <= 1:
            return P(*([dp] + [None] * max(0, nd - 1)))
        stacked = re.search(r"(^|/)body/", name) is not None
        if stacked:
            nd -= 1
        if nd == 4 and ("state" in name):
            spec = (dp, MODEL_AXIS, None, None)  # SSM (B,H,P,N)
        elif nd >= 2:
            if kv_shard == "seq":
                spec = (dp, MODEL_AXIS) + (None,) * (nd - 2)
            else:
                spec = (dp,) + (None,) * (nd - 2) + (MODEL_AXIS,)
        else:
            spec = (dp,)
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    return tree_map_with_name(leaf, cache_like)


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding on any dim whose size the mesh axes don't divide.

    Keeps the rules table simple (write the *intended* layout; odd vocab
    sizes like mamba2's 50280, MQA kv=1 heads, or batch-1 decode fall back to
    replication on that dim only).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        k = 1
        for a in axes:
            k *= sizes.get(a, 1)
        out.append(entry if (k > 0 and dim % k == 0) else None)
    return P(*out)


def shardings_for(mesh: Mesh, like_tree: Any, pspec_tree: Any) -> Any:
    """NamedShardings from a pspec tree, divisibility-sanitized per leaf."""
    return jax.tree_util.tree_map(
        lambda x, s: NamedSharding(mesh, sanitize_spec(s, tuple(x.shape), mesh)),
        like_tree,
        pspec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def state_pspecs(mesh: Mesh, state_like: Any, params_like: Any = None, *, fsdp: bool = True) -> Any:
    """PartitionSpecs for a full TrainState.

    Moment trees (m, v, precond, EF residuals, ASP masks) mirror the param
    specs: NamedTuple fields flatten to integer path segments, so stripping
    the leading numeric segments of each state leaf's path recovers the
    underlying parameter name, which is then run through the normal rules.
    Scalars / ring buffers / rng fall through to replicated.
    """

    def leaf(name: str, x):
        parts = name.split("/")
        while parts and parts[0].isdigit():
            parts = parts[1:]
        pname = "/".join(parts)
        if len(x.shape) >= 2 and pname:
            return param_pspec(pname, len(x.shape), fsdp=fsdp)
        return P()

    return tree_map_with_name(leaf, state_like)
