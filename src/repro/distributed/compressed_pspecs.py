"""PartitionSpecs for serving trees: ``CompressedTensor`` leaves + caches.

The training-side rules in ``distributed.sharding`` speak dense shapes.  A
serving tree is different in two ways:

1. **Compressed weights.**  An N:M-compressed leaf stores ``values`` /
   ``indices`` whose reduction axis has shrunk to ``n/m`` of the dense dim
   and whose groups must never straddle a shard (a shard owns whole M-wide
   groups or the ``nm_spmm`` decompress reads across devices).  The rule
   here derives each compressed leaf's spec *from the dense rule for the
   same leaf name*: TP lands on the non-compressed (output) dim by
   default, and stays on the compressed (reduction) dim only when the
   dense dim divides by ``M × axis_size`` — whole groups per shard.  Every
   leaf then runs through :func:`sharding.sanitize_spec` against its
   *stored* shape (alignment padding included), so odd vocab dims, tiny
   smoke shapes, and MQA heads degrade to replication per-dim instead of
   erroring.

2. **Serving caches.**  The slab cache reuses :func:`sharding.cache_pspecs`
   (sequence axis over ``model`` — context-parallel decode).  The paged
   pool has no per-lane sequence axis: its ``(num_pages, page_size, ...)``
   arrays shard the *pages* axis over ``model`` (``kv_shard="seq"``; each
   shard owns a slice of the physical pool, the sequence-sharding
   analogue) or the trailing feature axis (``kv_shard="feature"``).  Page
   tables are replicated — every shard resolves logical→physical addresses
   locally and the gather into the page-sharded pool is partitioned by
   GSPMD.  O(1) recurrent states stay lane-sharded over the DP axes.

Both entry points return trees aligned with the input tree (compressed
leaves map to a ``CompressedTensor`` whose children are the two specs /
shardings), so the results feed ``jax.jit(in_shardings=...)`` and
``jax.device_put`` directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    MODEL_AXIS,
    _dp,
    param_pspec,
    sanitize_spec,
)
from repro.models.cache import PagedLayout
from repro.sparse_infer.compress import CompressedTensor
from repro.utils.tree import _path_str


def _axis_size(entry, mesh: Mesh) -> int:
    """Total device count behind one spec entry (axis name or tuple)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = entry if isinstance(entry, tuple) else (entry,)
    k = 1
    for a in axes:
        k *= sizes.get(a, 1)
    return k


# leaves whose output dim reshapes to (heads, head_dim) and is then sliced
# (RoPE rotation halves, MLA nope/rope/v splits): TP on that dim must own
# *whole heads* — a partially-sharded head_dim is a resharding hazard and an
# observed XLA SPMD miscompile (CPU backend, jax 0.4.37: sharded-k RoPE
# returned wrong values, not just reordered sums).
_HEAD_GATED = (
    (re.compile(r"attn/(wq|bias_q|w_q)$"), lambda cfg: cfg.n_heads),
    (re.compile(r"attn/(wk|wv|bias_k|bias_v)$"), lambda cfg: cfg.n_kv),
    (re.compile(r"attn/w_ukv$"), lambda cfg: cfg.n_heads),
)
# output is a packed concat that downstream code slices apart (mamba2's
# (z, xbc, dt); the conv channels): never TP the packed dim
_SLICED_OUT = re.compile(r"mixer/(w_in|conv_w)$")
# MoE expert stacks: training shards the expert axis (EP) with the
# dispatch buffers constrained to match (``moe_mlp``'s ``ep_constraint``).
# The serving engine doesn't thread that constraint, and an
# expert-axis-sharded stack under plain GSPMD miscompiles the sort-based
# dispatch's sharded gathers (observed on the CPU backend, same class as
# the RoPE bug above) — serve them reduction-dim TP'd instead (exact
# psum); true EP serving is a ROADMAP item.
_EP_STACKS = re.compile(r"moe/w_(gate|up|down)_e$")


def _out_dim_ok(name: str, cfg, entry, mesh: Mesh) -> bool:
    """May ``entry`` shard this leaf's output dim?  (Head/concat gates.)"""
    if cfg is None:
        return True
    if _SLICED_OUT.search(name):
        return False
    for rx, h in _HEAD_GATED:
        if rx.search(name):
            return h(cfg) % _axis_size(entry, mesh) == 0
    return True


def _serving_entries(
    name: str, ndim: int, mesh: Mesh, cfg, *, fsdp: bool = False
) -> list:
    """Dense-rule spec entries adjusted for serving-time execution safety.

    When the arch config is known, TP entries that would shard *through* a
    head or packed-concat structure move to the reduction dim instead
    (partial matmul + psum — exact up to rounding, output replicated), and
    matmul weights the TP rules leave untouched (e.g. MLA's ``w_dkv``,
    whose dense rule is FSDP-only) get reduction-dim TP so serving never
    materializes a fully-replicated weight leaf.
    """
    base = param_pspec(name, ndim, fsdp=fsdp)
    entries = list(tuple(base)) + [None] * (ndim - len(tuple(base)))
    if cfg is None or ndim < 1:
        return entries
    if _EP_STACKS.search(name) and ndim >= 2:
        entries = [None] * ndim
        entries[-2] = MODEL_AXIS
        return entries
    is_bias = "bias" in name
    if entries[-1] is not None and not _out_dim_ok(
        name, cfg, entries[-1], mesh
    ):
        ent = entries[-1]
        entries[-1] = None
        if not is_bias and ndim >= 2 and entries[-2] is None:
            entries[-2] = ent  # reduction-dim TP: psum-exact
    from repro.core.sparsity_config import _EXCLUDE_FRAGMENTS

    if (
        not is_bias
        and ndim >= 2
        and MODEL_AXIS not in jax.tree_util.tree_leaves(entries)
        and not _SLICED_OUT.search(name)
        and entries[-2] is None
        and not any(f in name.lower() for f in _EXCLUDE_FRAGMENTS)
    ):
        # TP-orphaned matmul weight (serving runs fsdp-off; the masking
        # exclusions skip norms / embeddings / routers / recurrence
        # params): shard the reduction dim so every big weight leaf stays
        # distributed
        entries[-2] = MODEL_AXIS
    return entries


def compressed_pspec(
    name: str, ct: CompressedTensor, mesh: Mesh, *, cfg=None, fsdp: bool = False
) -> tuple[P, P]:
    """(values_spec, indices_spec) for one compressed leaf.

    Starts from the (serving-adjusted) dense rule for ``name`` at the
    stored rank (values keep the dense rank — only the reduction dim
    shrinks), then:

    - an entry on the compressed (group) axis survives only when the dense
      reduction dim divides by ``M × axis_size`` (whole N:M groups per
      shard); otherwise it moves to the output (non-compressed) dim when
      that dim is free, or drops;
    - both specs are sanitized against the stored shapes, so the
      MXU-alignment ``pad`` columns participate in divisibility.
    """
    v_shape = tuple(ct.values.shape)
    ndim = len(v_shape)
    entries = _serving_entries(name, ndim, mesh, cfg, fsdp=fsdp)
    gaxis = ndim - 2  # reduction axis; compress normalizes group_axis to -2
    entry = entries[gaxis]
    if entry is not None:
        k = _axis_size(entry, mesh)
        dense_in = v_shape[gaxis] * ct.m // max(ct.n, 1)
        if k <= 0 or dense_in % (ct.m * k) != 0:
            entries[gaxis] = None
            if entries[-1] is None and _out_dim_ok(name, cfg, entry, mesh):
                entries[-1] = entry  # fall back to the non-compressed dim
    spec = P(*entries)
    i_shape = tuple(ct.indices.shape)
    return (
        sanitize_spec(spec, v_shape, mesh),
        sanitize_spec(spec, i_shape, mesh),
    )


def _is_ct(x) -> bool:
    return isinstance(x, CompressedTensor)


def serving_param_pspecs(
    params_like: Any, mesh: Mesh, *, cfg=None, fsdp: bool = False
) -> Any:
    """PartitionSpec tree for a serving tree (dense and/or compressed).

    Serving defaults to TP-only (``fsdp=False``): decode reads every weight
    each step, so FSDP's gather-per-use buys nothing.  Passing the arch
    ``cfg`` enables the execution-safety gates (whole-head TP, packed
    concat dims, reduction-dim fallback — see :func:`_serving_entries`).
    Compressed leaves map to a ``CompressedTensor`` carrying the two specs
    as children, so the result tree flattens leaf-for-leaf against the
    input.
    """

    def leaf(path, x):
        name = _path_str(path)
        if _is_ct(x):
            v_spec, i_spec = compressed_pspec(name, x, mesh, cfg=cfg, fsdp=fsdp)
            return CompressedTensor(
                v_spec, i_spec, x.n, x.m, x.group_axis, x.shape, x.pad,
                x.rshards,
            )
        entries = _serving_entries(name, len(x.shape), mesh, cfg, fsdp=fsdp)
        return sanitize_spec(P(*entries), tuple(x.shape), mesh)

    return jax.tree_util.tree_map_with_path(leaf, params_like, is_leaf=_is_ct)


def annotate_reduction_tp(
    params: Any, mesh: Mesh, *, cfg=None, fsdp: bool = False
) -> Any:
    """Stamp ``CompressedTensor.rshards`` from the mesh placement.

    Computes the same pspecs :func:`serving_param_pspecs` would assign and,
    for every compressed leaf whose *group* (reduction) axis lands purely
    on the model axis, records that axis size as ``rshards`` in the leaf's
    static aux.  The matmul dispatch (``models.layers``) forwards it to the
    kernel registry so reduction-TP'd leaves can take the per-shard
    shard_map route (``kernels.sharded.nm_spmm_shard_map``) instead of
    relying on GSPMD to partition the XLA path.

    Must run *before* shardings/donation trees are built from the params
    tree: ``rshards`` lives in the pytree aux, so an annotated tree and an
    unannotated spec tree no longer match leaf-for-leaf.  The engine
    annotates right after construction, then derives everything else from
    the annotated tree.
    """

    def leaf(path, x):
        if not _is_ct(x):
            return x
        name = _path_str(path)
        v_spec, _ = compressed_pspec(name, x, mesh, cfg=cfg, fsdp=fsdp)
        ndim = x.values.ndim
        entries = list(tuple(v_spec)) + [None] * (ndim - len(tuple(v_spec)))
        entry = entries[ndim - 2]
        if entry != MODEL_AXIS:
            return x  # output-dim TP / replicated: GSPMD handles it
        return dataclasses.replace(x, rshards=_axis_size(entry, mesh))

    return jax.tree_util.tree_map_with_path(leaf, params, is_leaf=_is_ct)


def serving_param_shardings(
    mesh: Mesh, params_like: Any, *, cfg=None, fsdp: bool = False
) -> Any:
    """NamedSharding tree for ``jax.device_put`` / ``jit(in_shardings=...)``."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        serving_param_pspecs(params_like, mesh, cfg=cfg, fsdp=fsdp),
        is_leaf=lambda s: isinstance(s, P),
    )


def verifier_param_shardings(
    mesh: Mesh, params_like: Any, *, cfg=None
) -> Any:
    """NamedSharding tree for a speculative-decoding *verifier* tree.

    The verifier is the higher-fidelity twin of the served artifact — the
    dense source weights, or a looser N:M pattern (4:8 next to a 2:4
    drafter).  Its leaves take exactly the serving placement rules with
    FSDP off (the verify pass, like decode, reads every weight it touches
    in one dispatch, so there is no gather to amortize): dense leaves
    follow the dense TP rules, compressed leaves the ``compressed_pspec``
    derivation.  Kept as its own entry point so the engine's two parameter
    pytrees (drafter + verifier) visibly share one placement seam — the
    verify dispatch is mesh-native on the same ``("data", "model")`` mesh
    and under the shard_map kernel route, with no resharding between the
    draft scan and the verify pass.

    ``CompressedTensor`` verifier leaves must be ``annotate_reduction_tp``
    -stamped first, same as the serving tree (the engine does both).
    """
    return serving_param_shardings(mesh, params_like, cfg=cfg, fsdp=False)


def serving_cache_pspecs(
    mesh: Mesh, cache_like: Any, layout=None, *, kv_shard: str = "seq"
) -> Any:
    """PartitionSpec tree for a serving cache under either layout.

    Slab caches delegate to :func:`sharding.cache_pspecs` (sequence axis
    over ``model``).  Paged caches shard each layer's physical pool on the
    *pages* axis (``kv_shard="seq"``) or the trailing feature axis
    (``"feature"``), replicate the page tables, and keep O(1) recurrent
    states lane-sharded over DP.
    """
    if not isinstance(layout, PagedLayout):
        from repro.distributed.sharding import cache_pspecs

        return cache_pspecs(mesh, cache_like, kv_shard=kv_shard)

    dp = _dp(mesh)

    def leaf(path, x):
        name = _path_str(path)
        nd = len(x.shape)
        parts = name.split("/")
        if parts[0] == "tables":
            return P(*([None] * nd))  # replicated: local address resolution
        if parts[-1] == "len" or nd <= 1:
            return P(*([dp] + [None] * max(0, nd - 1)))
        stacked = re.search(r"(^|/)body/", name) is not None
        if stacked:
            nd -= 1
        if parts[-1].endswith("_scale"):
            # int8 page scale planes (P, ps): no feature axis, so they
            # shard with their pool's pages axis or replicate.
            if kv_shard == "seq":
                spec = (MODEL_AXIS,) + (None,) * (nd - 1)
            else:
                spec = (None,) * nd
        elif parts[-1] in ("k", "v", "ckv", "krope"):
            if kv_shard == "seq":
                spec = (MODEL_AXIS,) + (None,) * (nd - 1)  # pages axis
            else:
                spec = (None,) * (nd - 1) + (MODEL_AXIS,)  # feature axis
        elif nd == 4 and "state" in name:
            spec = (dp, MODEL_AXIS, None, None)  # SSM (B, H, P, N)
        else:
            spec = (dp,) + (None,) * (nd - 1)
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache_like)


def serving_cache_shardings(
    mesh: Mesh, cache_like: Any, layout=None, *, kv_shard: str = "seq"
) -> Any:
    """Divisibility-sanitized NamedShardings for a serving cache tree."""
    from repro.distributed.sharding import shardings_for

    return shardings_for(
        mesh, cache_like,
        serving_cache_pspecs(mesh, cache_like, layout, kv_shard=kv_shard),
    )


def check_kv_shard(mesh: Optional[Mesh], kv_shard: str) -> None:
    """Reject cache layouts that are known-broken on this mesh.

    ``kv_shard="feature"`` (trailing head/latent dim over ``model``) is
    **parked** on meshes with a >1 ``model`` axis: the prefill row-write
    over a feature-sharded slab reproducibly *miscompiles* under the XLA
    SPMD partitioner (CPU backend, jax 0.4.37 — wrong logits, the same
    "involuntary full rematerialization" class the seq-sharded write path
    was rewritten to avoid), and no parity test covers it.  It remains
    accepted on 1×1 meshes (where every sharding is trivial) so the knob
    stays exercisable.
    """
    if mesh is None or kv_shard != "feature":
        return
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if int(sizes.get(MODEL_AXIS, 1)) > 1:
        raise NotImplementedError(
            'kv_shard="feature" is not supported on meshes with a model '
            "axis > 1: the feature-sharded prefill write miscompiles under "
            "the XLA SPMD partitioner (observed wrong token streams). Use "
            'kv_shard="seq" (the default, and the measured-cheaper layout).'
        )


def lane_sharding(mesh: Mesh, max_batch: int) -> NamedSharding:
    """Sharding for per-lane ``(max_batch,)`` vectors: DP axes or replicated."""
    return NamedSharding(
        mesh, sanitize_spec(P(_dp(mesh)), (max_batch,), mesh)
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
