from repro.distributed.sharding import (
    param_pspec,
    tree_param_pspecs,
    batch_pspecs,
    cache_pspecs,
    state_pspecs,
    sanitize_spec,
    DP_AXES,
    MODEL_AXIS,
)
from repro.distributed.compressed_pspecs import (
    compressed_pspec,
    serving_param_pspecs,
    serving_param_shardings,
    serving_cache_pspecs,
    serving_cache_shardings,
)
