from repro.distributed.sharding import (
    param_pspec,
    tree_param_pspecs,
    batch_pspecs,
    cache_pspecs,
    state_pspecs,
    DP_AXES,
    MODEL_AXIS,
)
