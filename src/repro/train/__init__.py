from repro.train.loop import TrainState, Trainer, TrainerConfig, make_train_step
