"""The training loop: recipe + STEP optimizer + model, with fault tolerance.

``make_train_step`` builds the single jitted step implementing the paper's
Algorithm 1 end-to-end:

    masks  = recipe.masks_for_step(params, phase2)      # Π_t (or 1s)
    grads  = ∇ loss(Π_t ⊙ w; ζ_t)                        # STE forward
    grads += λ(1-Π_t)⊙w                                  # SR-STE (if recipe)
    grads  = pmean(compress(grads))                      # DP (+1-bit in p2)
    updates, opt = step_optimizer.update(grads, ...)     # 2-phase Adam
                                                         #  + AutoSwitch

:class:`Trainer` wraps the loop with checkpoint/auto-resume (kill -9 safe),
eval, telemetry, and a straggler deadline hook. The same Trainer object runs
the smoke tests, the paper-reproduction benchmarks, and (with pjit shardings
from launch/) the production meshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.recipes import Recipe, RecipeState
from repro.core.step_optimizer import StepConfig, StepState, step_optimizer
from repro.optim.base import GradientTransformation, apply_updates
from repro.optim.compression import (
    CompressionState,
    ef_sign_compress,
    init_compression_state,
)
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataIterator, IteratorState
from repro.utils.tree import global_norm


class TrainState(NamedTuple):
    params: Any
    opt: Any  # StepState (or any GradientTransformation state)
    recipe: RecipeState
    comp: Optional[CompressionState]
    rng: jnp.ndarray
    data_state: jnp.ndarray  # (2,) int32: (seed, step) mirror of the iterator


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 1000
    log_every: int = 50
    ckpt_every: int = 200
    eval_every: int = 0
    grad_clip: Optional[float] = 1.0
    compress_phase2: bool = False  # 1-bit EF gradient compression in phase 2
    donate: bool = True


def make_train_step(
    loss_fn: Callable[..., tuple[jnp.ndarray, dict]],
    recipe: Recipe,
    opt: GradientTransformation,
    *,
    grad_clip: Optional[float] = 1.0,
    compress_phase2: bool = False,
    axis_name: Optional[str] = None,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Build the jittable train step.

    ``loss_fn(params, batch) -> (loss, metrics)``; the recipe decides what
    the model sees. ``axis_name``: if set, gradients are psum-averaged over
    it (for shard_map/pmap use; under pjit the mean is implicit).
    """

    def step(state: TrainState, batch: Any) -> tuple[TrainState, dict]:
        phase2 = getattr(state.opt, "phase2", jnp.zeros((), jnp.bool_))
        mask, active, rstate = recipe.masks_for_step(
            state.params, state.recipe, phase2
        )

        def masked_loss(p):
            fp = recipe.forward_params(p, mask, active)
            return loss_fn(fp, batch)

        (loss, metrics), grads = jax.value_and_grad(masked_loss, has_aux=True)(
            state.params
        )
        grads = recipe.grad_postprocess(grads, state.params, mask, active)

        comp = state.comp
        if compress_phase2 and comp is not None:
            grads, comp = ef_sign_compress(grads, comp, phase2)
        if axis_name is not None:
            grads = jax.lax.pmean(grads, axis_name)
            loss = jax.lax.pmean(loss, axis_name)

        gnorm = global_norm(grads)
        if grad_clip is not None:
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        updates, ostate = opt.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(
            params=params,
            opt=ostate,
            recipe=rstate,
            comp=comp,
            rng=jax.random.fold_in(state.rng, 1),
            data_state=state.data_state + jnp.array([0, 1], jnp.int32),
        )
        metrics = dict(metrics)
        metrics.update(
            loss=loss,
            grad_norm=gnorm,
            phase2=phase2.astype(jnp.int32),
            mask_active=active.astype(jnp.int32),
        )
        if hasattr(ostate, "z_bar"):
            metrics["z_bar"] = ostate.z_bar
            metrics["t0"] = ostate.t0
        return new_state, metrics

    return step


@dataclasses.dataclass
class Trainer:
    """Fault-tolerant driver around ``make_train_step``."""

    loss_fn: Callable[..., tuple[jnp.ndarray, dict]]
    recipe: Recipe
    step_cfg: StepConfig
    data: DataIterator
    cfg: TrainerConfig = dataclasses.field(default_factory=TrainerConfig)
    checkpointer: Optional[Checkpointer] = None
    eval_fn: Optional[Callable[[Any, int], dict]] = None
    log_fn: Callable[[int, dict], None] = lambda step, m: None

    def __post_init__(self):
        self.opt = step_optimizer(self.step_cfg)
        self._step = jax.jit(
            make_train_step(
                self.loss_fn,
                self.recipe,
                self.opt,
                grad_clip=self.cfg.grad_clip,
                compress_phase2=self.cfg.compress_phase2,
            ),
            donate_argnums=(0,) if self.cfg.donate else (),
        )

    def init_state(self, params: Any, seed: int = 0) -> TrainState:
        # the jitted step donates its input state; copy the caller's params so
        # they survive the first step (callers reuse them for baselines/evals)
        params = jax.tree_util.tree_map(jnp.array, params)
        comp = (
            init_compression_state(params) if self.cfg.compress_phase2 else None
        )
        return TrainState(
            params=params,
            opt=self.opt.init(params),
            recipe=self.recipe.init_state(params),
            comp=comp,
            rng=jax.random.PRNGKey(seed),
            data_state=jnp.array([self.data.state.seed, self.data.state.step], jnp.int32),
        )

    # -- fault-tolerant run ---------------------------------------------------

    def restore_or_init(self, params: Any, seed: int = 0) -> tuple[TrainState, int]:
        state = self.init_state(params, seed)
        start = 0
        if self.checkpointer is not None:
            latest = self.checkpointer.latest_step()
            if latest is not None:
                state, meta = self.checkpointer.load(state)
                start = int(meta.get("step", latest))
                # resynchronize the data stream with the restored state
                ds = jax.device_get(state.data_state)
                self.data.set_state(IteratorState(int(ds[0]), int(ds[1])))
        return state, start

    def run(
        self, params: Any, seed: int = 0, step_timeout: Optional[float] = None
    ) -> tuple[TrainState, list[dict]]:
        """Train until total_steps, checkpointing and auto-resuming.

        ``step_timeout``: straggler deadline in seconds; a step exceeding it
        is logged (on a real cluster the launcher uses this signal to evict
        the slow host and restart from the last checkpoint — the elastic
        restore path exercised in tests)."""
        state, start = self.restore_or_init(params, seed)
        history: list[dict] = []
        for step in range(start, self.cfg.total_steps):
            batch = next(self.data)
            t0 = time.perf_counter()
            state, metrics = self._step(state, batch)
            if self.cfg.log_every and (step % self.cfg.log_every == 0):
                metrics = {
                    k: float(v) if jnp.ndim(v) == 0 else v for k, v in metrics.items()
                }
                metrics["step"] = step
                dt = time.perf_counter() - t0
                metrics["step_time_s"] = dt
                if step_timeout and dt > step_timeout:
                    metrics["straggler"] = True
                history.append(metrics)
                self.log_fn(step, metrics)
            if (
                self.checkpointer is not None
                and self.cfg.ckpt_every
                and step > 0
                and step % self.cfg.ckpt_every == 0
            ):
                self.checkpointer.save(step, state, {"step": step})
            if self.eval_fn is not None and self.cfg.eval_every and step % self.cfg.eval_every == 0:
                history.append({"step": step, **self.eval_fn(state.params, step)})
        if self.checkpointer is not None:
            self.checkpointer.save(self.cfg.total_steps, state, {"step": self.cfg.total_steps})
        return state, history
