"""Stateful, checkpointable, shardable data iterator.

The iterator's state is two integers (seed, step) because batches are pure
functions of them (synthetic.py). That makes exact restart trivial — the
checkpoint stores IteratorState; on resume the pipeline continues from the
same batch, on any device/host layout (each host materializes its own shard
by global batch index, so elastic re-mesh does not disturb the stream).

``prefetch`` runs generation one step ahead on a helper thread — the CPU
analogue of an infeed queue.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, NamedTuple, Optional

import jax
import numpy as np


class IteratorState(NamedTuple):
    seed: int
    step: int


@dataclasses.dataclass
class DataIterator:
    """Wraps a ``batch_fn(step, batch_size) -> pytree`` generator."""

    batch_fn: Callable[[int, int], Any]
    batch_size: int
    state: IteratorState = IteratorState(seed=0, step=0)
    prefetch: int = 2

    def __post_init__(self):
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- checkpointing ------------------------------------------------------

    def get_state(self) -> IteratorState:
        return self.state

    def set_state(self, state: IteratorState) -> None:
        self._shutdown()
        self.state = IteratorState(int(state.seed), int(state.step))

    # -- iteration ----------------------------------------------------------

    def _producer(self, start_step: int):
        step = start_step
        while not self._stop.is_set():
            batch = self.batch_fn(step, self.batch_size)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._q = queue.Queue(maxsize=self.prefetch)
            self._thread = threading.Thread(
                target=self._producer, args=(self.state.step,), daemon=True
            )
            self._thread.start()

    def __next__(self) -> Any:
        if self.prefetch > 0:
            self._ensure_thread()
            step, batch = self._q.get()
        else:
            step, batch = self.state.step, self.batch_fn(
                self.state.step, self.batch_size
            )
        self.state = IteratorState(self.state.seed, step + 1)
        return batch

    def __iter__(self) -> Iterator[Any]:
        return self

    def _shutdown(self):
        if self._thread is not None and self._thread.is_alive():
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
        self._thread = None
        self._q = None

    def close(self):
        self._shutdown()
