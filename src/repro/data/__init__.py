from repro.data.synthetic import SyntheticLMDataset, SyntheticTask, make_batch_specs
from repro.data.pipeline import DataIterator, IteratorState
