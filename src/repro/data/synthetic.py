"""Deterministic synthetic data (the container is offline; see DESIGN.md §8).

Two generators:

- :class:`SyntheticLMDataset` — a learnable formal language for LM training:
  tokens follow a randomly-drawn order-2 Markov chain with per-document seeds,
  so models genuinely reduce loss below ln(V) and recipe *comparisons* (dense
  vs ASP vs SR-STE vs STEP) are meaningful. Generation is a pure function of
  (seed, step), so any batch can be re-materialized after restart — the data
  pipeline's state is just two integers.

- :class:`SyntheticTask` — the teacher-student regression/classification task
  used by the paper-figure benchmarks where we need a *controlled* setting in
  which a 2:4-sparse student can represent the teacher exactly (the analogue
  of "the dense accuracy is reachable under the mask").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    n_states: int = 64  # Markov-chain state count (<= vocab)

    def _chain(self) -> np.ndarray:
        """Row-stochastic transition matrix (n_states, n_states), fixed."""
        rng = np.random.default_rng(self.seed)
        logits = rng.normal(size=(self.n_states, self.n_states)) * 2.0
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        return p / p.sum(axis=1, keepdims=True)

    def batch(self, step: int, batch_size: int) -> dict:
        """Materialize batch ``step`` — pure function of (seed, step)."""
        key = jax.random.PRNGKey(self.seed * 1_000_003 + step)
        chain = jnp.asarray(self._chain())  # (S0, S0)
        k0, k1 = jax.random.split(key)
        state0 = jax.random.randint(k0, (batch_size,), 0, self.n_states)

        def gen(carry, k):
            st = carry
            nxt = jax.random.categorical(k, jnp.log(chain[st] + 1e-9))
            return nxt, nxt

        keys = jax.random.split(k1, self.seq_len)
        _, seq = jax.lax.scan(gen, state0, keys)
        seq = jnp.moveaxis(seq, 0, 1)  # (B, S)
        tokens = seq % self.vocab
        return {
            "tokens": tokens[:, :].astype(jnp.int32),
            "labels": jnp.concatenate(
                [tokens[:, 1:], tokens[:, :1]], axis=1
            ).astype(jnp.int32),
        }


@dataclasses.dataclass(frozen=True)
class SyntheticTask:
    """Teacher-student task whose teacher is *exactly* N:M sparse, so the
    sparse-recipe gap to dense is attributable to optimization (the paper's
    regime), not representational capacity."""

    in_dim: int = 64
    out_dim: int = 32
    hidden: int = 128
    n: int = 2
    m: int = 4
    seed: int = 0
    noise: float = 0.01
    heavy_tail: bool = True  # gradient noise profile that stresses Adam's v

    def teacher(self) -> dict:
        from repro.core.masking import nm_mask

        k1, k2 = jax.random.split(jax.random.PRNGKey(self.seed))
        w1 = jax.random.normal(k1, (self.in_dim, self.hidden))
        w2 = jax.random.normal(k2, (self.hidden, self.out_dim))
        w1 = w1 * nm_mask(w1, self.n, self.m, 0)
        w2 = w2 * nm_mask(w2, self.n, self.m, 0)
        return {"w1": w1 / jnp.sqrt(self.in_dim), "w2": w2 / jnp.sqrt(self.hidden)}

    def student_init(self, key) -> dict:
        k1, k2 = jax.random.split(key)
        return {
            "fc1": {"w": jax.random.normal(k1, (self.in_dim, self.hidden)) * 0.05},
            "fc2": {"w": jax.random.normal(k2, (self.hidden, self.out_dim)) * 0.05},
        }

    def apply(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        h = jax.nn.relu(x @ params["fc1"]["w"])
        return h @ params["fc2"]["w"]

    def batch(self, step: int, batch_size: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        t = self.teacher()
        key = jax.random.PRNGKey(self.seed * 7_777_777 + step + 1)
        kx, kn, kh = jax.random.split(key, 3)
        x = jax.random.normal(kx, (batch_size, self.in_dim))
        y = jax.nn.relu(x @ t["w1"]) @ t["w2"]
        noise = self.noise * jax.random.normal(kn, y.shape)
        if self.heavy_tail:
            # occasional large-noise samples: the heavy-tailed gradient-noise
            # profile (Zhang et al. 2020) under which Adam >> SGD and the
            # paper's variance pathology is visible.
            spike = (jax.random.uniform(kh, (batch_size, 1)) < 0.05).astype(
                jnp.float32
            )
            noise = noise * (1.0 + 20.0 * spike)
        return x, y + noise

    def loss(self, params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        return jnp.mean(jnp.square(self.apply(params, x) - y))


def make_batch_specs(cfg: ArchConfig, batch_size: int, seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run input)."""
    from repro.models.model import frontend_dim

    specs = {
        "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
    }
    if cfg.frontend != "none":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (batch_size, seq_len, frontend_dim(cfg)), jnp.bfloat16
        )
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)
    return specs
