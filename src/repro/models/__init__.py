# Lazy exports to break the configs.base <-> models.model import cycle
# (configs.base needs the sub-config dataclasses from leaf modules;
#  models.model needs ArchConfig from configs.base).
_EXPORTS = ("TransformerLM", "init_params", "model_flops_per_token", "forward",
            "loss_fn", "decode_step", "prefill", "init_cache", "write_prefill",
            "param_count", "active_param_count", "layer_plan", "frontend_dim")


def __getattr__(name):
    if name in _EXPORTS:
        from repro.models import model as _m

        return getattr(_m, name)
    raise AttributeError(name)
