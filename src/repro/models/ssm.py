"""Mamba-2 (SSD — state-space duality) block.

Implements the chunked SSD algorithm from Dao & Gu (2024): within a chunk the
state-space kernel is computed as masked matmuls (MXU-friendly), across chunks
a linear recurrence carries the (H, P, N) state. Training/prefill use the
chunked form; decode is the O(1) recurrent update.

Layout: d_inner = expand * d_model, H = d_inner / head_dim heads, state size N,
B/C shared across heads per group (n_groups). The short depthwise conv and the
recurrence parameters (A_log, D, dt bias) are excluded from N:M masking (1-D /
tiny — see sparsity_config); the in/out projections, which hold ~95% of block
parameters, are masked.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import matmul


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


def ssm_dims(d_model: int, cfg: SSMConfig) -> dict:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    # in_proj packs [z, x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * cfg.n_groups * cfg.d_state + n_heads
    return dict(d_inner=d_inner, n_heads=n_heads, d_in_proj=d_in_proj)


def init_ssm_params(key, d_model: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> dict:
    dims = ssm_dims(d_model, cfg)
    di, nh = dims["d_inner"], dims["n_heads"]
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * cfg.n_groups * cfg.d_state
    return {
        "w_in": (
            jax.random.normal(ks[0], (d_model, dims["d_in_proj"]), jnp.float32)
            * (2.0 / (d_model + dims["d_in_proj"])) ** 0.5
        ).astype(dtype),
        "w_out": (
            jax.random.normal(ks[1], (di, d_model), jnp.float32)
            * (2.0 / (di + d_model)) ** 0.5
        ).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, conv_dim), jnp.float32) * 0.1
                   ).astype(dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(a_log), per head
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
    }


def _split_in_proj(zxbcdt: jnp.ndarray, d_model: int, cfg: SSMConfig):
    dims = ssm_dims(d_model, cfg)
    di, nh = dims["d_inner"], dims["n_heads"]
    gs = cfg.n_groups * cfg.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gs]
    dt = zxbcdt[..., di + di + 2 * gs :]  # (..., nh)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, conv_w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S. xbc: (B, S, C), conv_w: (W, C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(w)
    )
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """segsum(x)[..., i, j] = sum_{k=j+1..i} x[..., k] (lower-tri), -inf above.

    x: (..., Q) -> (..., Q, Q)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j) = sum_{j+1..i}
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) softplus'd
    a_log: jnp.ndarray,  # (H,)
    b: jnp.ndarray,  # (B, S, G, N)
    c: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    init_state=None,  # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xw = (x * dt[..., None]).astype(jnp.float32)  # dt-weighted input
    da = dt.astype(jnp.float32) * (-jnp.exp(a_log.astype(jnp.float32)))  # (B,S,H) <=0

    def resh(t, extra):  # (B, S, ...) -> (B, nc, chunk, ...)
        return t.reshape((bsz, nc, chunk) + extra)

    xc = resh(xw, (h, p))
    dac = resh(da, (h,))
    bc = resh(b.astype(jnp.float32), (g, n))
    cc = resh(c.astype(jnp.float32), (g, n))
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,chunk,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    # within-chunk (diagonal block): y_ij = C_i . B_j * exp(segsum) * x_j
    l = jnp.exp(_segsum(jnp.moveaxis(dac, -1, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bzqhn,bzkhn->bzhqk", ch, bh)  # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bzhqk,bzkhp->bzqhp", scores * l, xc)

    # per-chunk state contribution: S_z = sum_j exp(sum_{j+1..Q} da) B_j x_j
    cum = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bzqh,bzqhn,bzqhp->bzhpn", decay_to_end, bh, xc
    )  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay of a chunk

    # inter-chunk recurrence over z: S_out = decay * S_in + states_z
    def scan_fn(s_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev  # emit state *entering* the chunk

    s0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    s_final, s_enter = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_enter = jnp.moveaxis(s_enter, 0, 1)  # (B,nc,H,P,N)

    # off-diagonal contribution: y_i += C_i exp(cum_i) S_enter
    decay_from_start = jnp.exp(cum)  # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bzqhn,bzqh,bzhpn->bzqhp", ch, decay_from_start, s_enter
    )

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, s_final


def ssm_block(
    u: jnp.ndarray,  # (B, S, d_model)
    p: dict,
    d_model: int,
    cfg: SSMConfig,
    init_state=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full Mamba-2 mixer. Returns (out (B,S,d), (ssm_state, conv_tail))."""
    dims = ssm_dims(d_model, cfg)
    di, nh = dims["d_inner"], dims["n_heads"]
    g, n, hd = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = matmul(u, p["w_in"])
    z, xbc_raw, dt = _split_in_proj(zxbcdt, d_model, cfg)
    conv_tail = xbc_raw[:, -(cfg.conv_width - 1):, :]  # decode conv state
    xbc = _causal_conv(xbc_raw, p["conv_w"])
    x = xbc[..., :di]
    b = xbc[..., di : di + g * n]
    c = xbc[..., di + g * n :]
    bsz, s, _ = u.shape
    x = x.reshape(bsz, s, nh, hd)
    b = b.reshape(bsz, s, g, n)
    c = c.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    # largest divisor of S not exceeding the configured chunk (keeps odd test
    # lengths working; production shapes are multiples of cfg.chunk)
    chunk = min(cfg.chunk, s)
    while s % chunk:
        chunk -= 1
    y, s_final = ssd_chunked(x, dt, p["a_log"], b, c, chunk, init_state)
    y = y + p["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))  # gated
    return matmul(y.astype(u.dtype), p["w_out"]), (s_final, conv_tail)


def ssm_decode_step(
    u: jnp.ndarray,  # (B, 1, d_model)
    p: dict,
    d_model: int,
    cfg: SSMConfig,
    ssm_state: jnp.ndarray,  # (B, H, P, N)
    conv_state: jnp.ndarray,  # (B, W-1, conv_dim)
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent decode. Returns (out, new_ssm_state, new_conv_state)."""
    dims = ssm_dims(d_model, cfg)
    di, nh = dims["d_inner"], dims["n_heads"]
    g, n, hd = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = matmul(u, p["w_in"])
    z, xbc, dt = _split_in_proj(zxbcdt, d_model, cfg)
    # conv with rolled state
    full = jnp.concatenate([conv_state, xbc], axis=1)  # (B, W, C)
    w = p["conv_w"].shape[0]
    conv_out = sum(full[:, i : i + 1, :] * p["conv_w"][i][None, None, :] for i in range(w))
    xbc1 = jax.nn.silu(conv_out.astype(jnp.float32)).astype(u.dtype)
    new_conv_state = full[:, 1:, :]

    x = xbc1[..., :di].reshape(-1, nh, hd)  # (B,H,P)
    b = xbc1[..., di : di + g * n].reshape(-1, g, n)
    c = xbc1[..., di + g * n :].reshape(-1, g, n)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    da = jnp.exp(dt1 * (-jnp.exp(p["a_log"].astype(jnp.float32))))  # (B,H)
    rep = nh // g
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    chh = jnp.repeat(c, rep, axis=1).astype(jnp.float32)

    new_state = ssm_state * da[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", bh, x.astype(jnp.float32), dt1
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, chh)
    y = y + p["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(-1, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    return matmul(y.astype(u.dtype), p["w_out"]), new_state, new_conv_state
