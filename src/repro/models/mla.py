"""Multi-head Latent Attention (DeepSeek-V2).

MLA compresses K/V into a shared low-rank latent ``c_kv`` (kv_lora_rank wide)
plus a small shared RoPE key; per-head K(nope)/V are up-projected from the
latent. At decode time only ``(c_kv, k_rope)`` is cached — the KV cache is
``kv_lora + rope_dim`` wide per token instead of ``2 · H · head_dim``, an
~18× reduction for DeepSeek-V2-Lite. This is the architecture's whole point
and our serve path honors it: the cache stores latents and decode re-expands
K/V on the fly (bandwidth-for-compute trade — the right direction on TPU
where HBM bandwidth dominates decode).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models.cache import PagedLayout, SlabLayout
from repro.models.layers import apply_rope, chunked_attention, decode_attention, matmul
from repro.sparse_infer.compress import CompressedTensor


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


def init_mla_params(
    key, d_model: int, n_heads: int, cfg: MLAConfig, dtype=jnp.bfloat16
) -> dict:
    ks = jax.random.split(key, 6)
    sc = lambda i, o: (2.0 / (i + o)) ** 0.5
    qd = n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
    kvd = n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
    od = n_heads * cfg.v_head_dim
    mk = lambda k, i, o: (
        jax.random.normal(k, (i, o), jnp.float32) * sc(i, o)
    ).astype(dtype)
    return {
        "w_q": mk(ks[0], d_model, qd),
        "w_dkv": mk(ks[1], d_model, cfg.kv_lora + cfg.rope_head_dim),
        "w_ukv": mk(ks[2], cfg.kv_lora, kvd),
        "w_o": mk(ks[3], od, d_model),
    }


def _project_qkv(x, p, n_heads: int, cfg: MLAConfig):
    b, s, _ = x.shape
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = matmul(x, p["w_q"]).reshape(b, s, n_heads, nd + rd)
    dkv = matmul(x, p["w_dkv"])  # (B,S,kv_lora + rd)
    c_kv, k_rope = dkv[..., : cfg.kv_lora], dkv[..., cfg.kv_lora :]
    return q, c_kv, k_rope


def _expand_kv(c_kv, p, n_heads: int, cfg: MLAConfig):
    b, s, _ = c_kv.shape
    nd, vd = cfg.nope_head_dim, cfg.v_head_dim
    ukv = matmul(c_kv, p["w_ukv"]).reshape(b, s, n_heads, nd + vd)
    return ukv[..., :nd], ukv[..., nd:]  # k_nope, v


def _absorbed_ukv(p, n_heads: int, cfg: MLAConfig):
    """``(W_uk, W_uv)`` as ``(kv_lora, H, nd)`` / ``(kv_lora, H, vd)`` for
    the latent-space (absorbed) decode.

    A compressed ``w_ukv`` is decompressed here *inside the jitted step*:
    that trades one weight's worth of decompress work for skipping the
    per-token ``(B, S, H, nd+vd)`` K/V expansion — strictly less compute
    and HBM traffic than the reference path, which reads the same weight
    *and* runs the expansion matmul over every cached token.
    """
    w = p["w_ukv"]
    wd = w.dense() if isinstance(w, CompressedTensor) else w
    nd, vd = cfg.nope_head_dim, cfg.v_head_dim
    wd = wd.reshape(cfg.kv_lora, n_heads, nd + vd)
    return wd[..., :nd], wd[..., nd:]


def mla_attention(
    x: jnp.ndarray,  # (B, S, d)
    p: dict,
    n_heads: int,
    cfg: MLAConfig,
    positions: jnp.ndarray,
    rope_theta: float = 10000.0,
    chunk: int = 512,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence MLA (train / prefill). Returns (out, (c_kv, k_rope))."""
    b, s, _ = x.shape
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q, c_kv, k_rope = _project_qkv(x, p, n_heads, cfg)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_rope_r = apply_rope(k_rope[:, :, None, :], positions, rope_theta)  # (B,S,1,rd)
    k_nope, v = _expand_kv(c_kv, p, n_heads, cfg)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,nd+rd)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_r, (b, s, n_heads, rd))], axis=-1
    )
    # pad v to the same head dim so one attention kernel serves both
    out = chunked_attention(qf, kf, v_pad(v, nd + rd), causal=True, chunk=chunk)
    out = out[..., :vd].reshape(b, s, n_heads * vd)
    return matmul(out, p["w_o"]), (c_kv, k_rope_r[:, :, 0, :])


def v_pad(v: jnp.ndarray, to: int) -> jnp.ndarray:
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, to - v.shape[-1])))


def mla_chunk(
    x: jnp.ndarray,  # (L, C, d) — one prompt chunk per chunking lane
    p: dict,
    n_heads: int,
    cfg: MLAConfig,
    cache: dict,
    lanes,  # (L,) int32 (a lane >= the batch size marks a padding row)
    starts,  # (L,) int32: position of x[r, 0] in lane r's sequence
    lengths,  # (L,) int32: valid tokens per row (rest is padding)
    rope_theta: float = 10000.0,
    layout=None,
    tables=None,
    chunk: int = 512,
) -> tuple[jnp.ndarray, dict]:
    """One batched chunked-prefill step: row ``r`` writes its latents at
    positions ``starts[r]..starts[r]+lengths[r]-1`` of ``lanes[r]``, then
    attends its queries over that lane's whole cached prefix (the per-row
    ``q_offset`` supplies the causal offsets).  Pad rows produce garbage
    that the caller discards — only position ``lengths[r]-1``'s logits are
    consumed, and only on a lane's final chunk.

    This same seam scores speculative drafts: the verify pass feeds the
    last committed token plus the ``gamma`` drafts as one ``gamma+1``-wide
    chunk, so every draft position's latents are (re)written at verifier
    fidelity and attended causally in a single dispatch —
    ``model.prefill_chunk(all_logits=True)`` then unembeds every slot
    instead of only ``lengths[r]-1``."""
    if layout is None:
        layout = SlabLayout()
    b, csz, _ = x.shape
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    positions = starts[:, None] + jnp.arange(csz)[None, :]  # (L, C)
    q, c_kv, k_rope = _project_qkv(x, p, n_heads, cfg)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_rope_r = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :]

    new_cache = layout.mla_write_chunk(
        cache, c_kv, k_rope_r, lanes, starts, lengths, tables
    )
    ckv_view, krope_view = layout.mla_chunk_view(new_cache, lanes, tables)
    k_nope, v = _expand_kv(ckv_view, p, n_heads, cfg)
    s = ckv_view.shape[1]
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_view[:, :, None, :], (b, s, n_heads, rd))],
        axis=-1,
    )
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(
        qf, kf, v_pad(v, nd + rd), causal=True, q_offset=starts, chunk=chunk
    )
    out = out[..., :vd].reshape(b, csz, n_heads * vd)
    return matmul(out, p["w_o"]), new_cache


def mla_decode(
    x: jnp.ndarray,  # (B, 1, d)
    p: dict,
    n_heads: int,
    cfg: MLAConfig,
    cache: dict,  # {"ckv", "krope"} — slab (B,S,..) or paged (P,ps,..)
    cache_len,  # (B,) int32
    rope_theta: float = 10000.0,
    layout=None,
    tables=None,
) -> tuple[jnp.ndarray, dict]:
    """One decode step; re-expands K/V from the latent cache.

    The cache entry is read and written through ``layout``
    (``models.cache.SlabLayout`` by default, or a ``PagedLayout`` whose
    page ``tables`` map logical positions to pool pages).  Returns
    (out, new_cache_entry); the caller advances cache_len.
    """
    if layout is None:
        layout = SlabLayout()
    b = x.shape[0]
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    pos = jnp.reshape(jnp.asarray(cache_len), (-1,))  # (B,)
    q, c_kv_new, k_rope_new = _project_qkv(x, p, n_heads, cfg)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, pos[:, None], rope_theta)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], pos[:, None], rope_theta)[
        :, :, 0, :
    ]

    if isinstance(layout, PagedLayout) and dispatch.uses_kernel(
        "paged_attn", b=b, n_slots=tables["full"].shape[1],
        page_size=layout.page_size, num_pages=layout.num_pages,
        shards=layout.shards,
    ):
        # fast path: attend *in latent space* through the page table.
        # W_ukv is absorbed into the query / output projections
        # (DeepSeek-V2's decode identity: q·(c W_uk) = (q W_ukᵀ)·c and
        # Σ p·(c W_uv) = (Σ p·c) W_uv), so the per-token K/V expansion —
        # and the contiguous (B, S, H, nd+vd) views it fed — vanish; the
        # kernel streams each live latent page exactly once (V *is* the
        # latent: ``v_is_k``).
        new_cache = layout.mla_write(
            cache, c_kv_new[:, 0], k_rope_new[:, 0], pos, tables
        )
        wk, wv = _absorbed_ukv(p, n_heads, cfg)
        q_lat = jnp.einsum(
            "bhd,lhd->bhl",
            q_nope[:, 0].astype(jnp.float32), wk.astype(jnp.float32),
        )  # (B, H, kv_lora)
        o_lat = dispatch.paged_attn(
            q_lat[:, None],  # (B, 1, H, kv_lora): Hkv=1, G=H
            new_cache["ckv"][:, :, None, :], None, tables["full"], pos + 1,
            scale=(nd + rd) ** -0.5,
            q2=q_rope[:, 0].astype(jnp.float32)[:, None],
            k2_pages=new_cache["krope"][:, :, None, :],
            v_is_k=True,
            shards=layout.shards,
            k_scale=new_cache.get("ckv_scale"),
            k2_scale=new_cache.get("krope_scale"),
        )  # (B, 1, H, kv_lora)
        out = jnp.einsum(
            "bhl,lhv->bhv", o_lat[:, 0], wv.astype(jnp.float32)
        ).astype(x.dtype)
        out = out.reshape(b, 1, n_heads * vd)
        return matmul(out, p["w_o"]), new_cache

    # reference path: write the new latent at position cache_len; read back
    # the logical view and re-expand K/V per token
    ckv_view, krope_view, new_cache = layout.mla_rw(
        cache, c_kv_new[:, 0], k_rope_new[:, 0], pos, tables
    )

    # expand the whole latent view to per-head K/V (bandwidth → compute)
    k_nope, v = _expand_kv(ckv_view, p, n_heads, cfg)  # (B,S,H,nd/vd)
    s = ckv_view.shape[1]
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_view[:, :, None, :], (b, s, n_heads, rd))],
        axis=-1,
    )
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = decode_attention(qf, kf, v_pad(v, nd + rd), pos + 1)
    out = out[..., :vd].reshape(b, 1, n_heads * vd)
    return matmul(out, p["w_o"]), new_cache
