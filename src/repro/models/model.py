"""The architecture zoo's single entry point: a configurable decoder LM.

One code path covers all 10 assigned architectures:

- mixers: GQA attention (RoPE / M-RoPE / QKV-bias / sliding window),
  MLA (DeepSeek latent attention), Mamba-2 SSD, RG-LRU (Griffin).
- MLPs: SwiGLU, GeLU, MoE (top-k, shared experts), or none (Mamba-2).
- heterogenous stacks via ``layer_plan``: a periodic super-block is scanned
  (``lax.scan`` keeps HLO size O(1) in depth — 80-layer dry-runs compile),
  with optional non-periodic head/tail layers applied individually
  (DeepSeek's dense first layer; RecurrentGemma's 38 = 12×(rec,rec,attn)+2).

The model is sparsity-agnostic in two senses: during training, recipes mask
the *parameter tree* before it reaches ``forward`` (see core/recipes.py),
exactly like the paper applies Π⊙w per training step; at serving time, the
parameter tree may hold ``sparse_infer.CompressedTensor`` leaves — every
weight matmul dispatches through ``layers.matmul``, so ``prefill`` and
``decode_step`` run directly on the N:M-compressed artifact (the
``repro.serving`` engine's fast path; no dense rehydration in HBM).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models import cache as C
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import recurrent as REC
from repro.models import ssm as SSM

# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    head: tuple[str, ...]  # kinds of unscanned leading layers
    period: tuple[str, ...]  # the scanned super-block's kinds
    n_body: int  # number of scanned super-blocks
    tail: tuple[str, ...]  # kinds of unscanned trailing layers


def layer_plan(cfg: ArchConfig) -> LayerPlan:
    kinds = cfg.block_kinds()
    head: list[str] = []
    if cfg.moe is not None and cfg.moe.first_layer_dense:
        head = [kinds[0] + ":dense"]
        kinds = kinds[1:]
    if cfg.layer_pattern is None:
        period = (kinds[0],) if kinds else ()
        return LayerPlan(tuple(head), period, len(kinds), ())
    p = len(cfg.layer_pattern)
    n_body = len(kinds) // p
    tail = tuple(kinds[n_body * p :])
    return LayerPlan(tuple(head), tuple(cfg.layer_pattern), n_body, tail)


def _block_mixer_mlp(kind: str, cfg: ArchConfig) -> tuple[str, str]:
    """kind string -> (mixer, mlp_kind)."""
    force_dense = kind.endswith(":dense")
    base = kind.split(":")[0]
    if base == "ssm":
        mixer = "ssm"
        mlp = "none"
    elif base == "rec":
        mixer = "rec"
        mlp = "dense"
    else:  # attn
        mixer = "mla" if cfg.mla is not None else "attn"
        mlp = "moe" if (cfg.moe is not None and not force_dense) else "dense"
    if force_dense:
        mlp = "dense"
    return mixer, mlp


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_norm(cfg: ArchConfig, d: int) -> dict:
    if cfg.norm == "rms":
        return {"norm_scale": jnp.zeros((d,), jnp.float32)}
    return {
        "norm_scale": jnp.ones((d,), jnp.float32),
        "norm_bias": jnp.zeros((d,), jnp.float32),
    }


def _apply_norm(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "rms":
        return L.rmsnorm(x, p["norm_scale"])
    return L.layernorm(x, p["norm_scale"], p["norm_bias"])


def _init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, h * hd, dtype),
        "wk": L.dense_init(ks[1], d, kv * hd, dtype),
        "wv": L.dense_init(ks[2], d, kv * hd, dtype),
        "wo": L.dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((h * hd,), dtype)
        p["bias_k"] = jnp.zeros((kv * hd,), dtype)
        p["bias_v"] = jnp.zeros((kv * hd,), dtype)
    if cfg.o_bias:
        p["bias_o"] = jnp.zeros((d,), dtype)
    return p


def _init_mlp(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": L.dense_init(ks[0], d, f, dtype),
            "w_up": L.dense_init(ks[1], d, f, dtype),
            "w_down": L.dense_init(ks[2], f, d, dtype),
        }
    return {
        "w_fc": L.dense_init(ks[0], d, f, dtype),
        "w_proj": L.dense_init(ks[1], f, d, dtype),
    }


def _init_block(key, kind: str, cfg: ArchConfig, dtype) -> dict:
    mixer, mlp = _block_mixer_mlp(kind, cfg)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"pre": _init_norm(cfg, d)}
    if mixer == "attn":
        p["attn"] = _init_attn(k1, cfg, dtype)
    elif mixer == "mla":
        p["attn"] = MLA.init_mla_params(k1, d, cfg.n_heads, cfg.mla, dtype)
    elif mixer == "ssm":
        p["mixer"] = SSM.init_ssm_params(k1, d, cfg.ssm, dtype)
    elif mixer == "rec":
        p["mixer"] = REC.init_rglru_params(k1, d, cfg.rglru, dtype)
    if mlp != "none":
        p["post"] = _init_norm(cfg, d)
        if mlp == "moe":
            p["moe"] = MOE.init_moe_params(k2, d, cfg.moe, dtype)
        else:
            p["mlp"] = _init_mlp(k2, cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": {"tok_embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)},
        "final": _init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "out_embed": L.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
        }
    if cfg.frontend != "none":
        fdim = frontend_dim(cfg)
        params["frontend"] = {
            "frontend_proj": L.dense_init(keys[2], fdim, cfg.d_model, dtype)
        }
    for i, kind in enumerate(plan.head):
        params[f"head_{i}"] = _init_block(
            jax.random.fold_in(keys[3], i), kind, cfg, dtype
        )
    if plan.n_body:
        def one(k):
            sb = {}
            for j, kind in enumerate(plan.period):
                sb[f"sb_{j}"] = _init_block(jax.random.fold_in(k, j), kind, cfg, dtype)
            return sb

        body_keys = jax.random.split(keys[4], plan.n_body)
        params["body"] = jax.vmap(one)(body_keys)
    for i, kind in enumerate(plan.tail):
        params[f"tail_{i}"] = _init_block(
            jax.random.fold_in(keys[5], i), kind, cfg, dtype
        )
    return params


def frontend_dim(cfg: ArchConfig) -> int:
    return {"audio_stub": 512, "vision_stub": 1176}.get(cfg.frontend, 0)


# ---------------------------------------------------------------------------
# forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _attn_forward(
    x, p, cfg: ArchConfig, positions, *, chunk: int, want_cache: bool
):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = L.matmul(x, p["wq"])
    k = L.matmul(x, p["wk"])
    v = L.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bias_q"], k + p["bias_k"], v + p["bias_v"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.rope == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = L.apply_mrope(q, positions, theta=cfg.rope_theta)
        k = L.apply_mrope(k, positions, theta=cfg.rope_theta)
    out = L.chunked_attention(
        q, k, v, causal=True, window=cfg.local_window, chunk=chunk
    )
    out = L.matmul(out.reshape(b, s, h * hd), p["wo"])
    if cfg.o_bias:
        out = out + p["bias_o"]
    cache = (k, v) if want_cache else None
    return out, cache


def _block_forward(
    x,
    p: dict,
    kind: str,
    cfg: ArchConfig,
    positions,
    *,
    chunk: int = 512,
    want_cache: bool = False,
    ep_constraint=None,
):
    """Full-seq block. Returns (x_out, aux_loss, cache_entry)."""
    mixer, mlp = _block_mixer_mlp(kind, cfg)
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = _apply_norm(cfg, p["pre"], x)
    if mixer == "attn":
        mix_out, cache = _attn_forward(
            h, p["attn"], cfg, positions, chunk=chunk, want_cache=want_cache
        )
    elif mixer == "mla":
        pos1d = positions if positions.ndim == 2 else positions[..., 0]
        mix_out, lat = MLA.mla_attention(
            h, p["attn"], cfg.n_heads, cfg.mla, pos1d, cfg.rope_theta, chunk
        )
        cache = lat if want_cache else None
    elif mixer == "ssm":
        mix_out, state = SSM.ssm_block(h, p["mixer"], cfg.d_model, cfg.ssm)
        cache = state if want_cache else None  # (ssm_state, conv_tail)
    elif mixer == "rec":
        mix_out, state, conv_state = REC.rglru_block(h, p["mixer"], cfg.rglru)
        cache = (state, conv_state) if want_cache else None
    else:
        raise AssertionError(mixer)
    x = x + mix_out
    if mlp != "none":
        h2 = _apply_norm(cfg, p["post"], x)
        if mlp == "moe":
            mo, a = MOE.moe_mlp(h2, p["moe"], cfg.moe, ep_constraint=ep_constraint)
            aux = aux + a
        elif cfg.mlp == "swiglu":
            mo = L.swiglu_mlp(h2, p["mlp"])
        else:
            mo = L.gelu_mlp(h2, p["mlp"])
        x = x + mo
    return x, aux, cache


def _default_positions(cfg: ArchConfig, b: int, s: int, offset=0):
    pos = offset + jnp.arange(s)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def forward(
    params: dict,
    cfg: ArchConfig,
    batch: dict,
    *,
    chunk: int = 512,
    remat: bool = True,
    want_cache: bool = False,
    block_constraint=None,
    ep_constraint=None,
) -> tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Full-sequence forward.

    ``batch``: {"tokens": (B,S) int32} or {"embeds": (B,S,F)} for stub
    frontends; optional {"positions"}. Returns (logits, aux_loss, caches).

    ``block_constraint``: optional fn applied to the residual stream at
    layer boundaries — the launch layer injects
    ``lax.with_sharding_constraint`` here (e.g. sequence-parallel residuals),
    which pins the remat-saved activations' layout under pjit.
    """
    plan = layer_plan(cfg)
    if "embeds" in batch and cfg.frontend != "none":
        x = L.matmul(batch["embeds"], params["frontend"]["frontend_proj"])
        b, s = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["embed"]["tok_embed"][tokens]
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, b, s)

    if block_constraint is not None:
        x = block_constraint(x)
    aux = jnp.zeros((), jnp.float32)
    caches: dict = {}

    for i, kind in enumerate(plan.head):
        x, a, c = _block_forward(
            x, params[f"head_{i}"], kind, cfg, positions,
            chunk=chunk, want_cache=want_cache, ep_constraint=ep_constraint,
        )
        aux += a
        if want_cache:
            caches[f"head_{i}"] = c

    if plan.n_body:
        def superblock(x, p_sb):
            a_tot = jnp.zeros((), jnp.float32)
            cs = {}
            for j, kind in enumerate(plan.period):
                x, a, c = _block_forward(
                    x, p_sb[f"sb_{j}"], kind, cfg, positions,
                    chunk=chunk, want_cache=want_cache, ep_constraint=ep_constraint,
                )
                a_tot += a
                if want_cache:
                    cs[f"sb_{j}"] = c
            if block_constraint is not None:
                x = block_constraint(x)
            return x, (a_tot, cs if want_cache else None)

        sb_fn = jax.checkpoint(superblock) if remat else superblock

        def scan_body(x, p_sb):
            return sb_fn(x, p_sb)

        x, (a_list, c_stack) = jax.lax.scan(scan_body, x, params["body"])
        aux += jnp.sum(a_list)
        if want_cache:
            caches["body"] = c_stack

    for i, kind in enumerate(plan.tail):
        x, a, c = _block_forward(
            x, params[f"tail_{i}"], kind, cfg, positions,
            chunk=chunk, want_cache=want_cache, ep_constraint=ep_constraint,
        )
        aux += a
        if want_cache:
            caches[f"tail_{i}"] = c

    x = _apply_norm(cfg, params["final"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["tok_embed"].T  # embeddings stay dense
    else:
        logits = L.matmul(x, params["unembed"]["out_embed"])
    return logits, aux, (caches if want_cache else None)


def loss_fn(
    params: dict, cfg: ArchConfig, batch: dict, *, chunk: int = 512,
    remat: bool = True, aux_weight: float = 0.01, z_weight: float = 1e-4,
    block_constraint=None, ep_constraint=None, logits_constraint=None,
) -> tuple[jnp.ndarray, dict]:
    logits, aux, _ = forward(params, cfg, batch, chunk=chunk, remat=remat,
                             block_constraint=block_constraint,
                             ep_constraint=ep_constraint)
    if logits_constraint is not None:
        # keep logits vocab-sharded through the loss: logsumexp reduces over
        # the sharded vocab dim (GSPMD psums a (B,S) scalar field instead of
        # all-gathering the (B,S,V) logits — §Perf hillclimb #1)
        logits = logits_constraint(logits)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    nll = lse - ll
    zloss = jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll * mask) / denom
        zl = jnp.sum(zloss * mask) / denom
    else:
        ce = jnp.mean(nll)
        zl = jnp.mean(zloss)
    total = ce + aux_weight * aux + z_weight * zl
    return total, {"ce": ce, "aux": aux, "zloss": zl}


# ---------------------------------------------------------------------------
# KV-cache serving path
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ArchConfig, batch_size: int, max_len: int, dtype=None, layout=None
) -> dict:
    """Allocate the decode cache for every layer.

    ``layout`` (default :class:`models.cache.SlabLayout`) owns the storage
    geometry of attention / MLA entries — contiguous per-lane slabs or a
    paged ``(num_pages, page_size, ...)`` pool with page tables.  SSM and
    RG-LRU states are O(1) per lane and stay slot-indexed either way.
    """
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    if layout is None:
        layout = C.SlabLayout(max_len)
    plan = layer_plan(cfg)

    def one(kind: str) -> Any:
        mixer, _ = _block_mixer_mlp(kind, cfg)
        if mixer == "attn":
            return layout.attn_alloc(
                batch_size, cfg.local_window, cfg.n_kv, cfg.hd, dtype
            )
        if mixer == "mla":
            return layout.mla_alloc(
                batch_size, cfg.mla.kv_lora, cfg.mla.rope_head_dim, dtype
            )
        if mixer == "ssm":
            dims = SSM.ssm_dims(cfg.d_model, cfg.ssm)
            conv_dim = dims["d_inner"] + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
            return {
                "state": jnp.zeros(
                    (batch_size, dims["n_heads"], cfg.ssm.head_dim, cfg.ssm.d_state),
                    jnp.float32,
                ),
                "conv": jnp.zeros(
                    (batch_size, cfg.ssm.conv_width - 1, conv_dim), dtype
                ),
            }
        if mixer == "rec":
            w = cfg.rglru.lru_width
            return {
                "state": jnp.zeros((batch_size, w), jnp.float32),
                "conv": jnp.zeros(
                    (batch_size, cfg.rglru.conv_width - 1, w), dtype
                ),
            }
        raise AssertionError(mixer)

    cache: dict = {"len": jnp.zeros((batch_size,), jnp.int32)}
    for i, kind in enumerate(plan.head):
        cache[f"head_{i}"] = one(kind)
    if plan.n_body:
        sb = {f"sb_{j}": one(kind) for j, kind in enumerate(plan.period)}
        cache["body"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (plan.n_body,) + x.shape).copy(), sb
        )
    for i, kind in enumerate(plan.tail):
        cache[f"tail_{i}"] = one(kind)
    tables = layout.tables(batch_size)
    if tables is not None:
        cache["tables"] = tables
    return cache


def write_prefill(
    cache: dict, cfg: ArchConfig, produced: dict, lanes, lens, layout=None
) -> dict:
    """Write a batch of freshly prefilled rows into the serving cache pool.

    ``produced`` is the per-layer cache tuple tree from
    ``forward(want_cache=True)`` over the (possibly padded) prompt batch;
    row ``r`` is valid up to ``lens[r]`` tokens and lands in lane
    ``lanes[r]`` (a lane index ≥ the pool's batch size marks a padding row
    and is dropped).  The layout owns the attention/MLA storage geometry;
    SSM / RG-LRU states scatter into their lanes directly, so recurrent
    rows must be *exact length* (``lens[r] == prompt length``) — the
    engine pads only attention-family archs.
    """
    if layout is None:
        layout = C.SlabLayout()
    plan = layer_plan(cfg)
    tables = cache.get("tables")

    def wr(kind: str, c, pr):
        mixer, _ = _block_mixer_mlp(kind, cfg)
        if mixer == "attn":
            k, v = pr
            return layout.attn_write_rows(
                c, k, v, lanes, lens, tables, cfg.local_window
            )
        if mixer == "mla":
            ckv, krope = pr
            return layout.mla_write_rows(c, ckv, krope, lanes, lens, tables)
        if mixer == "ssm":
            st, tail = pr
            # short prompts: left-pad the conv tail with zeros
            w1 = c["conv"].shape[1]
            tail = tail.astype(c["conv"].dtype)
            if tail.shape[1] < w1:
                pad = jnp.zeros(
                    (tail.shape[0], w1 - tail.shape[1], tail.shape[2]), tail.dtype
                )
                tail = jnp.concatenate([pad, tail], axis=1)
            return {
                "state": c["state"].at[lanes].set(st, mode="drop"),
                "conv": c["conv"].at[lanes].set(tail, mode="drop"),
            }
        if mixer == "rec":
            st, cv = pr
            return {
                "state": c["state"].at[lanes].set(st, mode="drop"),
                "conv": c["conv"].at[lanes].set(
                    cv.astype(c["conv"].dtype), mode="drop"
                ),
            }
        raise AssertionError(mixer)

    out = dict(cache)
    out["len"] = cache["len"].at[lanes].set(lens, mode="drop")
    for i, kind in enumerate(plan.head):
        out[f"head_{i}"] = wr(kind, cache[f"head_{i}"], produced[f"head_{i}"])
    if plan.n_body:
        def wr_sb(c_sb, pr_sb):
            return {
                f"sb_{j}": wr(kind, c_sb[f"sb_{j}"], pr_sb[f"sb_{j}"])
                for j, kind in enumerate(plan.period)
            }

        out["body"] = jax.vmap(wr_sb)(cache["body"], produced["body"])
    for i, kind in enumerate(plan.tail):
        out[f"tail_{i}"] = wr(kind, cache[f"tail_{i}"], produced[f"tail_{i}"])
    return out


def reset_lanes(cfg: ArchConfig, cache: dict, mask) -> dict:
    """Zero the recurrent (SSM / RG-LRU) state rows of masked lanes.

    The device-resident scheduler refills a freed lane *inside* the decode
    loop: paged/slab attention entries need no reset — stale KV is dead
    under the lane's length mask once ``cache["len"]`` rewinds to 0 — but
    O(1) recurrent states are read unconditionally, so masked lanes' rows
    must return to the zeros a fresh prompt starts from.  ``mask`` is
    ``(B,)`` bool; attention-only archs pass through untouched.
    """
    plan = layer_plan(cfg)

    def zero(c: dict, stacked: bool) -> dict:
        def z(x):
            m = mask[None, :] if stacked else mask  # body leaves: (n_body, B, ...)
            mm = m.reshape(m.shape + (1,) * (x.ndim - m.ndim))
            return jnp.where(mm, jnp.zeros_like(x), x)

        return {k: z(v) for k, v in c.items()}

    def recurrent(kind: str) -> bool:
        return _block_mixer_mlp(kind, cfg)[0] in ("ssm", "rec")

    out = dict(cache)
    for i, kind in enumerate(plan.head):
        if recurrent(kind):
            out[f"head_{i}"] = zero(cache[f"head_{i}"], False)
    if plan.n_body and any(recurrent(k) for k in plan.period):
        body = dict(cache["body"])
        for j, kind in enumerate(plan.period):
            if recurrent(kind):
                body[f"sb_{j}"] = zero(cache["body"][f"sb_{j}"], True)
        out["body"] = body
    for i, kind in enumerate(plan.tail):
        if recurrent(kind):
            out[f"tail_{i}"] = zero(cache[f"tail_{i}"], False)
    return out


def _attn_chunk(x, p, cfg: ArchConfig, c: dict, lanes, starts, lengths,
                layout, tables, chunk: int):
    """One prompt chunk per chunking lane, batched: row ``r`` writes K/V at
    ``starts[r]..starts[r]+lengths[r]-1`` of lane ``lanes[r]`` and attends
    its queries over that lane's whole cached prefix.

    x: (L, C, d).  Non-windowed attention reads the append-only full view;
    sliding-window layers on a paged layout read the modular-table view —
    the last ``win + C - 1`` positions ending at the chunk's final token
    (everything a ``win``-wide window can reach), with the below-zero left
    edge masked via ``kv_valid_from``.  Windowed *slab* caches stay gated
    off chunking by the engine."""
    b, csz, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = L.matmul(x, p["wq"])
    k = L.matmul(x, p["wk"])
    v = L.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bias_q"], k + p["bias_k"], v + p["bias_v"]
    q = q.reshape(b, csz, h, hd)
    k = k.reshape(b, csz, kv, hd)
    v = v.reshape(b, csz, kv, hd)
    posb = starts[:, None] + jnp.arange(csz)[None, :]  # (L, C)
    if cfg.rope == "rope":
        q = L.apply_rope(q, posb, cfg.rope_theta)
        k = L.apply_rope(k, posb, cfg.rope_theta)
    elif cfg.rope == "mrope":
        p3 = jnp.broadcast_to(posb[..., None], (b, csz, 3))
        q = L.apply_mrope(q, p3, theta=cfg.rope_theta)
        k = L.apply_mrope(k, p3, theta=cfg.rope_theta)
    windowed = isinstance(layout, C.PagedLayout) and layout._windowed(
        cfg.local_window
    )
    new_c = layout.attn_write_chunk(
        c, k, v, lanes, starts, lengths, tables,
        window=cfg.local_window if windowed else None,
    )
    # pad rows (i >= length, or a sentinel lane) attend garbage — discarded
    # by the caller, which reads logits only at row length-1 (and only on
    # the final chunk)
    if windowed:
        win = min(layout.max_len, cfg.local_window)
        k_view, v_view = layout.attn_chunk_view_win(
            new_c, lanes, starts, csz, cfg.local_window, tables
        )
        out = L.chunked_attention(
            q, k_view, v_view, causal=True, window=win,
            q_offset=win - 1,  # q[0] sits at view slot S_v - C = win - 1
            kv_valid_from=jnp.maximum(0, win - 1 - starts),
            chunk=chunk,
        )
    else:
        k_view, v_view = layout.attn_chunk_view(new_c, lanes, tables)
        out = L.chunked_attention(
            q, k_view, v_view, causal=True, q_offset=starts, chunk=chunk
        )
    out = L.matmul(out.reshape(b, csz, h * hd), p["wo"])
    if cfg.o_bias:
        out = out + p["bias_o"]
    return out, new_c


def _block_chunk(x, p, kind: str, cfg: ArchConfig, c, lanes, starts, lengths,
                 layout, tables, chunk: int):
    mixer, mlp = _block_mixer_mlp(kind, cfg)
    if mixer not in ("attn", "mla"):
        raise NotImplementedError(
            "chunked prefill requires attention-family mixers (recurrent "
            "state cannot resume mid-prompt); the engine gates this"
        )
    h = _apply_norm(cfg, p["pre"], x)
    if mixer == "attn":
        mix_out, c = _attn_chunk(
            h, p["attn"], cfg, c, lanes, starts, lengths, layout, tables, chunk
        )
    else:
        mix_out, c = MLA.mla_chunk(
            h, p["attn"], cfg.n_heads, cfg.mla, c, lanes, starts, lengths,
            cfg.rope_theta, layout=layout, tables=tables, chunk=chunk,
        )
    x = x + mix_out
    if mlp != "none":
        h2 = _apply_norm(cfg, p["post"], x)
        if mlp == "moe":
            mo, _ = MOE.moe_mlp(h2, p["moe"], cfg.moe)
        elif cfg.mlp == "swiglu":
            mo = L.swiglu_mlp(h2, p["mlp"])
        else:
            mo = L.gelu_mlp(h2, p["mlp"])
        x = x + mo
    return x, c


def prefill_chunk(
    params: dict, cfg: ArchConfig, tokens: jnp.ndarray, cache: dict,
    lanes, starts, lengths, layout=None, *, chunk: int = 512,
    all_logits: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """Process one fixed-size prompt chunk of every chunking lane against
    the live serving cache: tokens (L, C) int32 (row ``r`` valid below
    ``lengths[r]``) → (logits (L, V) at each row's last valid position,
    new cache).

    This is the incremental counterpart of ``prefill``: each chunk's K/V
    (or MLA latents) are scattered into its lane's cache slots at
    positions ``starts[r]..starts[r]+lengths[r]-1`` and its queries attend
    through the cached prefix, so long prompts are absorbed across several
    small dispatches that the engine interleaves with decode dispatches
    instead of one monolithic head-of-line-blocking forward — and **one**
    dispatch absorbs a chunk of *every* currently-chunking lane (rows with
    a sentinel lane index are padding and write nothing).  The returned
    logits matter only on each lane's final chunk (they seed its first
    sampled token).  Attention-family archs only; the cache's ``len`` for
    ``lanes[r]`` advances to ``starts[r] + lengths[r]``.

    ``all_logits=True`` is the speculative-verify seam: the unembed runs
    over the *whole* chunk and logits come back as ``(L, C, V)`` — row
    ``r`` slot ``j`` scores position ``starts[r] + j``, i.e. the verifier
    distribution for the token *after* ``tokens[r, j]``.  Pad slots
    (``j >= lengths[r]``) are garbage and must be masked by the caller.
    """
    if layout is None:
        layout = C.SlabLayout()
    plan = layer_plan(cfg)
    tables = cache.get("tables")
    x = params["embed"]["tok_embed"][tokens]  # (L, C, d)
    new_cache: dict = {
        "len": cache["len"].at[lanes].set(
            (starts + lengths).astype(cache["len"].dtype), mode="drop"
        )
    }
    if tables is not None:
        new_cache["tables"] = tables

    for i, kind in enumerate(plan.head):
        x, c = _block_chunk(
            x, params[f"head_{i}"], kind, cfg, cache[f"head_{i}"], lanes,
            starts, lengths, layout, tables, chunk,
        )
        new_cache[f"head_{i}"] = c

    if plan.n_body:
        def scan_body(x, pc):
            p_sb, c_sb = pc
            cs = {}
            for j, kind in enumerate(plan.period):
                x, cj = _block_chunk(
                    x, p_sb[f"sb_{j}"], kind, cfg, c_sb[f"sb_{j}"], lanes,
                    starts, lengths, layout, tables, chunk,
                )
                cs[f"sb_{j}"] = cj
            return x, cs

        x, body_cache = jax.lax.scan(scan_body, x, (params["body"], cache["body"]))
        new_cache["body"] = body_cache

    for i, kind in enumerate(plan.tail):
        x, c = _block_chunk(
            x, params[f"tail_{i}"], kind, cfg, cache[f"tail_{i}"], lanes,
            starts, lengths, layout, tables, chunk,
        )
        new_cache[f"tail_{i}"] = c

    if all_logits:
        # speculative verify: score every chunk slot in one unembed —
        # slot j of row r is the verifier distribution at starts[r] + j
        xn = _apply_norm(cfg, params["final"], x)
        if cfg.tie_embeddings:
            logits_all = xn @ params["embed"]["tok_embed"].T
        else:
            logits_all = L.matmul(xn, params["unembed"]["out_embed"])
        return logits_all, new_cache
    # logits only at each row's last valid position — the unembed matmul
    # runs on one token per row, not the whole chunk
    idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)  # (L, 1, d)
    x_last = _apply_norm(cfg, params["final"], x_last)
    if cfg.tie_embeddings:
        logits = x_last @ params["embed"]["tok_embed"].T
    else:
        logits = L.matmul(x_last, params["unembed"]["out_embed"])
    return logits[:, 0, :], new_cache


def _attn_decode(x, p, cfg: ArchConfig, c: dict, pos, layout, tables):
    """x: (B,1,d). pos: (B,) positions of the new token."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = L.matmul(x, p["wq"])
    k = L.matmul(x, p["wk"])
    v = L.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bias_q"], k + p["bias_k"], v + p["bias_v"]
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kv, hd)
    v = v.reshape(b, 1, kv, hd)
    posb = jnp.reshape(pos, (b, 1))
    if cfg.rope == "rope":
        q = L.apply_rope(q, posb, cfg.rope_theta)
        k = L.apply_rope(k, posb, cfg.rope_theta)
    elif cfg.rope == "mrope":
        p3 = jnp.broadcast_to(posb[..., None], (b, 1, 3))
        q = L.apply_mrope(q, p3, theta=cfg.rope_theta)
        k = L.apply_mrope(k, p3, theta=cfg.rope_theta)

    if isinstance(layout, C.PagedLayout) and dispatch.uses_kernel(
        "paged_attn", b=b, n_slots=tables[layout.table_key(cfg.local_window)].shape[1],
        page_size=layout.page_size, num_pages=layout.num_pages,
        shards=layout.shards,
    ):
        # fast path: scatter the new token into its page, then attend
        # through the page table directly — no contiguous (B, S, ...) K/V
        # view is gathered per step (kernels/paged_attn.py)
        new_c = layout.attn_write(
            c, k[:, 0], v[:, 0], pos, tables, cfg.local_window
        )
        win = layout.view_window(cfg.local_window)
        qg = q[:, 0].reshape(b, kv, h // kv, hd)
        out = dispatch.paged_attn(
            qg, new_c["k"], new_c["v"],
            tables[layout.table_key(cfg.local_window)], pos + 1,
            scale=hd ** -0.5, window=win,
            win_slots=layout.pages_win if win else 0,
            shards=layout.shards,
            k_scale=new_c.get("k_scale"), v_scale=new_c.get("v_scale"),
        )
        out = out.reshape(b, 1, h, hd)
    else:
        # reference path: write the new token, read the logical
        # (oldest→newest) view back — through the slab or the page table,
        # the decode math is the same
        k_view, v_view, new_c = layout.attn_rw(
            c, k[:, 0], v[:, 0], pos, tables, cfg.local_window
        )
        s_view = k_view.shape[1]
        out = L.decode_attention(
            q, k_view, v_view, jnp.minimum(pos, s_view - 1) + 1
        )
    out = L.matmul(out.reshape(b, 1, h * hd), p["wo"])
    if cfg.o_bias:
        out = out + p["bias_o"]
    return out, new_c


def _block_decode(x, p, kind: str, cfg: ArchConfig, c, pos, layout, tables):
    mixer, mlp = _block_mixer_mlp(kind, cfg)
    h = _apply_norm(cfg, p["pre"], x)
    if mixer == "attn":
        mix_out, c = _attn_decode(h, p["attn"], cfg, c, pos, layout, tables)
    elif mixer == "mla":
        mix_out, c = MLA.mla_decode(
            h, p["attn"], cfg.n_heads, cfg.mla, c, pos, cfg.rope_theta,
            layout=layout, tables=tables,
        )
    elif mixer == "ssm":
        mix_out, st, cv = SSM.ssm_decode_step(
            h, p["mixer"], cfg.d_model, cfg.ssm, c["state"], c["conv"]
        )
        c = {"state": st, "conv": cv}
    elif mixer == "rec":
        mix_out, st, cv = REC.rglru_decode_step(
            h, p["mixer"], cfg.rglru, c["state"], c["conv"]
        )
        c = {"state": st, "conv": cv}
    x = x + mix_out
    if mlp != "none":
        h2 = _apply_norm(cfg, p["post"], x)
        if mlp == "moe":
            mo, _ = MOE.moe_mlp(h2, p["moe"], cfg.moe)
        elif cfg.mlp == "swiglu":
            mo = L.swiglu_mlp(h2, p["mlp"])
        else:
            mo = L.gelu_mlp(h2, p["mlp"])
        x = x + mo
    return x, c


def decode_step(
    params: dict, cfg: ArchConfig, tokens: jnp.ndarray, cache: dict, layout=None
) -> tuple[jnp.ndarray, dict]:
    """One serving step: tokens (B,) int32 -> (logits (B,V), new cache).

    ``layout`` selects the cache storage geometry (slab default / paged);
    a paged cache carries its page tables in ``cache["tables"]``, which
    pass through unchanged (the host-side pool manager owns them).
    """
    if layout is None:
        layout = C.SlabLayout()
    plan = layer_plan(cfg)
    pos = cache["len"]  # (B,)
    tables = cache.get("tables")
    x = params["embed"]["tok_embed"][tokens][:, None, :]  # (B,1,d)
    new_cache: dict = {"len": cache["len"] + 1}
    if tables is not None:
        new_cache["tables"] = tables

    for i, kind in enumerate(plan.head):
        x, c = _block_decode(
            x, params[f"head_{i}"], kind, cfg, cache[f"head_{i}"], pos,
            layout, tables,
        )
        new_cache[f"head_{i}"] = c

    if plan.n_body:
        def scan_body(x, pc):
            p_sb, c_sb = pc
            cs = {}
            for j, kind in enumerate(plan.period):
                x, cj = _block_decode(
                    x, p_sb[f"sb_{j}"], kind, cfg, c_sb[f"sb_{j}"], pos,
                    layout, tables,
                )
                cs[f"sb_{j}"] = cj
            return x, cs

        x, body_cache = jax.lax.scan(scan_body, x, (params["body"], cache["body"]))
        new_cache["body"] = body_cache

    for i, kind in enumerate(plan.tail):
        x, c = _block_decode(
            x, params[f"tail_{i}"], kind, cfg, cache[f"tail_{i}"], pos,
            layout, tables,
        )
        new_cache[f"tail_{i}"] = c

    x = _apply_norm(cfg, params["final"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["tok_embed"].T  # embeddings stay dense
    else:
        logits = L.matmul(x, params["unembed"]["out_embed"])
    return logits[:, 0, :], new_cache


def prefill(
    params: dict, cfg: ArchConfig, batch: dict, max_len: int, *, chunk: int = 512
) -> tuple[jnp.ndarray, dict]:
    """Process a prompt; build the decode cache. Returns (last logits, cache)."""
    logits, _, caches = forward(
        params, cfg, batch, chunk=chunk, remat=False, want_cache=True
    )
    if "tokens" in batch:
        b, s = batch["tokens"].shape
    else:
        b, s = batch["embeds"].shape[:2]
    cache = init_cache(cfg, b, max_len)
    cache["len"] = jnp.full((b,), s, jnp.int32)

    def fill(kind: str, c, produced):
        mixer, _ = _block_mixer_mlp(kind, cfg)
        if mixer == "attn":
            k, v = produced
            sc = c["k"].shape[1]
            if sc >= s:
                return {
                    "k": jax.lax.dynamic_update_slice(c["k"], k, (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(c["v"], v, (0, 0, 0, 0)),
                }
            return {"k": k[:, -sc:], "v": v[:, -sc:]}  # window cache
        if mixer == "mla":
            ckv, krope = produced
            return {
                "ckv": jax.lax.dynamic_update_slice(c["ckv"], ckv, (0, 0, 0)),
                "krope": jax.lax.dynamic_update_slice(c["krope"], krope, (0, 0, 0)),
            }
        if mixer == "ssm":
            st, tail = produced
            # short prompts: left-pad the conv tail with the cache's zeros
            w1 = c["conv"].shape[1]
            tail = tail.astype(c["conv"].dtype)
            if tail.shape[1] < w1:
                tail = jnp.concatenate(
                    [c["conv"][:, : w1 - tail.shape[1]], tail], axis=1
                )
            return {"state": st, "conv": tail}
        if mixer == "rec":
            st, cv = produced
            return {"state": st, "conv": cv.astype(c["conv"].dtype)}
        raise AssertionError(mixer)

    plan = layer_plan(cfg)
    for i, kind in enumerate(plan.head):
        cache[f"head_{i}"] = fill(kind, cache[f"head_{i}"], caches[f"head_{i}"])
    if plan.n_body:
        # vmapped fill over the body stack
        def fill_sb(c_sb, pr_sb):
            return {
                f"sb_{j}": fill(kind, c_sb[f"sb_{j}"], pr_sb[f"sb_{j}"])
                for j, kind in enumerate(plan.period)
            }

        cache["body"] = jax.vmap(fill_sb)(cache["body"], caches["body"])
    for i, kind in enumerate(plan.tail):
        cache[f"tail_{i}"] = fill(kind, cache[f"tail_{i}"], caches[f"tail_{i}"])
    return logits[:, -1, :], cache


# ---------------------------------------------------------------------------
# analytics
# ---------------------------------------------------------------------------


def param_count(cfg: ArchConfig) -> int:
    import math

    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return sum(
        math.prod(x.shape) for x in jax.tree_util.tree_leaves(shapes)
    )


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    expert_p = 3 * cfg.d_model * cfg.moe.d_ff_expert
    plan = layer_plan(cfg)
    n_moe = sum(
        1
        for kind in (
            list(plan.head)
            + list(plan.period) * plan.n_body
            + list(plan.tail)
        )
        if _block_mixer_mlp(kind, cfg)[1] == "moe"
    )
    return total - n_moe * (e - k) * expert_p


def model_flops_per_token(cfg: ArchConfig, seq_len: int) -> float:
    """MODEL_FLOPS/token = 6·N_active (+ attention quadratic term)."""
    n_active = active_param_count(cfg)
    flops = 6.0 * n_active
    # causal attention: 12 * L_attn * H * hd * S/2 per token (fwd+bwd ~ 3x fwd)
    plan = layer_plan(cfg)
    kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
    n_attn = sum(1 for k in kinds if _block_mixer_mlp(k, cfg)[0] in ("attn", "mla"))
    w = cfg.local_window
    eff_s = seq_len if w is None else min(w, seq_len)
    flops += 6.0 * n_attn * cfg.n_heads * cfg.hd * (eff_s / 2) * 2
    return flops


class TransformerLM:
    """Thin OO wrapper tying an ArchConfig to the functional API."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(self.cfg, key)

    def loss(self, params, batch, **kw):
        return loss_fn(params, self.cfg, batch, **kw)

    def forward(self, params, batch, **kw):
        return forward(params, self.cfg, batch, **kw)

    def prefill(self, params, batch, max_len, **kw):
        return prefill(params, self.cfg, batch, max_len, **kw)

    def decode_step(self, params, tokens, cache, layout=None):
        return decode_step(params, self.cfg, tokens, cache, layout)

    def prefill_chunk(self, params, tokens, cache, lanes, starts, lengths,
                      layout=None, **kw):
        return prefill_chunk(
            params, self.cfg, tokens, cache, lanes, starts, lengths, layout, **kw
        )

    def init_cache(self, batch_size, max_len, dtype=None, layout=None):
        return init_cache(self.cfg, batch_size, max_len, dtype, layout)

    def write_prefill(self, cache, produced, lanes, lens, layout=None):
        return write_prefill(cache, self.cfg, produced, lanes, lens, layout)

    def reset_lanes(self, cache, mask):
        return reset_lanes(self.cfg, cache, mask)
