"""Mixture-of-Experts MLP with sort-based capacity dispatch (EP-shardable).

Design notes (vs the GShard one-hot einsum): the dense ``(T, E, C)`` dispatch
tensor is O(T·E·C) and explodes at 64 experts × 65k tokens/shard, so we use
the MaxText-style sort-and-scatter formulation instead:

  1. top-k routing per token,
  2. stable-sort the (token, expert) pairs by expert,
  3. each pair's slot = expert·C + rank-within-expert (overflow dropped),
  4. scatter token activations into an ``(E, C, d)`` buffer,
  5. grouped expert matmuls ``(E, C, d) @ (E, d, f)``,
  6. gather-scatter back with the gate weights.

The ``(E, C, d)`` buffer carries a sharding constraint on E (the ``model``
mesh axis) so experts are parallelized (EP) and GSPMD inserts the all-to-all;
token activations stay sharded on the data axis throughout.

A standard load-balancing auxiliary loss (Switch-style) is returned so the
training objective is complete.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import matmul


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # DeepSeek-style always-on shared experts
    capacity_factor: float = 1.25
    first_layer_dense: bool = False  # DeepSeek: layer 0 uses a dense MLP
    router_dtype: str = "float32"


def moe_capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 5)
    scale_in = (2.0 / (d_model + cfg.d_ff_expert)) ** 0.5
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": (
            jax.random.normal(ks[0], (d_model, e), jnp.float32) * 0.02
        ).astype(jnp.float32),
        "w_gate_e": (
            jax.random.normal(ks[1], (e, d_model, f), jnp.float32) * scale_in
        ).astype(dtype),
        "w_up_e": (
            jax.random.normal(ks[2], (e, d_model, f), jnp.float32) * scale_in
        ).astype(dtype),
        "w_down_e": (
            jax.random.normal(ks[3], (e, f, d_model), jnp.float32) * scale_in
        ).astype(dtype),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * f
        sc = (2.0 / (d_model + fs)) ** 0.5
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(kk[0], (d_model, fs), jnp.float32) * sc).astype(dtype),
            "w_up": (jax.random.normal(kk[1], (d_model, fs), jnp.float32) * sc).astype(dtype),
            "w_down": (jax.random.normal(kk[2], (fs, d_model), jnp.float32) * sc).astype(dtype),
        }
    return p


def moe_mlp(
    x: jnp.ndarray,  # (B, S, d)
    p: dict,
    cfg: MoEConfig,
    *,
    ep_constraint=None,  # callable: (E,C,d)-array -> sharded array (EP)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(t, cfg)

    if ep_constraint is not None:
        xt = ep_constraint(xt)  # (T, d): keep tokens dp-sharded, replicated
        # over model, so the dispatch gathers below stay shard-local
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(probs, k)  # (T, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch, GATHER-ONLY on the wide tensors.
    # Scatters of (T, d)/(E, C, d) activations partition terribly under GSPMD
    # (measured: the .at[slot].set/add formulation all-reduces the full f32
    # buffer per layer — tens of GB/step/device on dbrx). All big-tensor data
    # movement below is expressed as gathers; the only scatters touch int32
    # index vectors of size E*C / T*k (~MBs).
    fe = top_i.reshape(-1)  # (T*k,) expert of each pair
    fg = top_g.reshape(-1)
    ftok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(fe, stable=True)
    se, stok = fe[order], ftok[order]
    counts = jnp.zeros((e,), jnp.int32).at[fe].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)  # overflow -> scratch

    # slot -> source token (int32 scatter; sentinel t = zero row)
    slot_tok = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(stok)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    buf = xt_pad[slot_tok[: e * cap]].reshape(e, cap, d)  # gather
    if ep_constraint is not None:
        buf = ep_constraint(buf)

    # ---- grouped expert matmuls (per-expert; MXU-friendly). matmul
    # broadcasts over the expert axis for dense (E, in, out) stacks and
    # vmaps the compressed kernel over it for CompressedTensor leaves.
    gate = jax.nn.silu(matmul(buf, p["w_gate_e"]).astype(jnp.float32))
    up = matmul(buf, p["w_up_e"]).astype(jnp.float32)
    h = (gate * up).astype(x.dtype)
    out_e = matmul(h, p["w_down_e"])  # (E, C, d)
    if ep_constraint is not None:
        out_e = ep_constraint(out_e)

    # ---- combine: per-token gather of its k expert outputs
    # pair -> slot in unsorted pair order (int32 scatter, small)
    pair_slot = jnp.zeros((t * k,), jnp.int32).at[order].set(slot)
    flat = jnp.concatenate(
        [out_e.reshape(e * cap, d), jnp.zeros((1, d), out_e.dtype)], axis=0
    )
    per_pair = flat[pair_slot].reshape(t, k, d)  # gather
    yt = jnp.sum(
        per_pair.astype(jnp.float32) * top_g[..., None].astype(jnp.float32), axis=1
    )

    if cfg.n_shared:
        sp = p["shared"]
        g2 = jax.nn.silu(matmul(xt, sp["w_gate"]).astype(jnp.float32))
        u2 = matmul(xt, sp["w_up"]).astype(jnp.float32)
        yt = yt + matmul((g2 * u2).astype(x.dtype), sp["w_down"]).astype(jnp.float32)

    return yt.astype(x.dtype).reshape(b, s, d), aux
