"""RG-LRU recurrent block (RecurrentGemma / Griffin, De et al. 2024).

The recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
a_t = exp(-c · softplus(Λ) · r_t),  r_t = σ(W_a x_t),  i_t = σ(W_x x_t),
is a diagonal linear RNN — we evaluate it with ``jax.lax.associative_scan``
(log-depth, TPU-friendly) for train/prefill and an O(1) update for decode.

Block layout (Griffin recurrent block): two input projections (wide branch +
gate branch), short depthwise conv on the wide branch, RG-LRU, gated merge,
output projection. In/out projections are N:M-maskable; the diagonal Λ and
the conv are excluded.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import matmul


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int  # recurrence width (RecurrentGemma: == d_model)
    conv_width: int = 4
    c: float = 8.0  # Griffin's fixed scaling constant


def init_rglru_params(key, d_model: int, cfg: RGLRUConfig, dtype=jnp.bfloat16) -> dict:
    w = cfg.lru_width
    ks = jax.random.split(key, 6)
    sc = lambda i, o: (2.0 / (i + o)) ** 0.5
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, w), jnp.float32) * sc(d_model, w)).astype(dtype),
        "w_gate_branch": (jax.random.normal(ks[1], (d_model, w), jnp.float32) * sc(d_model, w)).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (w, d_model), jnp.float32) * sc(w, d_model)).astype(dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32) * 0.1).astype(dtype),
        # RG-LRU gates: per-channel input projections (thin: w x w would be
        # huge; Griffin uses block-diagonal — we use per-channel vectors,
        # excluded from masking as recurrence parameters)
        "w_a_gate": (jax.random.normal(ks[4], (d_model, w), jnp.float32) * sc(d_model, w)).astype(dtype),
        "w_i_gate": (jax.random.normal(ks[5], (d_model, w), jnp.float32) * sc(d_model, w)).astype(dtype),
        "a_log_lambda": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w))).astype(
            jnp.float32
        ),  # softplus^-1 of Λ
    }


def _causal_conv(x: jnp.ndarray, conv_w: jnp.ndarray) -> jnp.ndarray:
    w = conv_w.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1], :] * conv_w[i][None, None, :] for i in range(w))


def rglru_scan(
    x: jnp.ndarray,  # (B, S, W) conv'd branch
    u: jnp.ndarray,  # (B, S, d_model) block input (for the gates)
    p: dict,
    cfg: RGLRUConfig,
    init_state=None,  # (B, W)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (h (B,S,W) f32, final_state (B,W) f32)."""
    lam = jax.nn.softplus(p["a_log_lambda"])  # (W,) > 0
    r = jax.nn.sigmoid(matmul(u, p["w_a_gate"]).astype(jnp.float32))  # (B,S,W)
    i = jax.nn.sigmoid(matmul(u, p["w_i_gate"]).astype(jnp.float32))
    log_a = -cfg.c * lam[None, None, :] * r  # (B,S,W)  (<= 0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * i * x.astype(jnp.float32)
    if init_state is not None:
        # fold the carried state in as a virtual step 0
        bx = bx.at[:, 0, :].add(a[:, 0, :] * init_state.astype(jnp.float32))

    # associative scan over the linear recurrence h_t = a_t h_{t-1} + bx_t
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    del a_sc
    return h, h[:, -1, :]


def rglru_block(
    u: jnp.ndarray,  # (B, S, d_model)
    p: dict,
    cfg: RGLRUConfig,
    init_state=None,
    conv_state=None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full Griffin recurrent block. Returns (out, lru_state, conv_state)."""
    x = matmul(u, p["w_x"])
    gate = jax.nn.gelu(matmul(u, p["w_gate_branch"]).astype(jnp.float32), approximate=True)
    if conv_state is not None:
        w = p["conv_w"].shape[0]
        full = jnp.concatenate([conv_state, x], axis=1)
        xc = sum(
            full[:, i : i + x.shape[1], :] * p["conv_w"][i][None, None, :]
            for i in range(w)
        )
        new_conv_state = full[:, x.shape[1] :, :]
    else:
        xc = _causal_conv(x, p["conv_w"])
        new_conv_state = x[:, -(p["conv_w"].shape[0] - 1) :, :]
    h, final = rglru_scan(xc, u, p, cfg, init_state)
    y = (h * gate).astype(u.dtype)
    return matmul(y, p["w_out"]), final, new_conv_state


def rglru_decode_step(
    u: jnp.ndarray,  # (B, 1, d_model)
    p: dict,
    cfg: RGLRUConfig,
    lru_state: jnp.ndarray,  # (B, W)
    conv_state: jnp.ndarray,  # (B, conv_width-1, W)
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    y, final, new_conv = rglru_block(u, p, cfg, lru_state, conv_state)
    return y, final, new_conv
