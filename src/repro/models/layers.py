"""Shared neural-net layers: norms, RoPE/M-RoPE, chunked attention, MLPs.

Conventions
-----------
- Matmul weights are stored ``(in_features, out_features)`` (``y = x @ W``)
  so N:M sparsity groups run along axis 0 — the reduction axis.
- All layers are pure functions over explicit parameter dicts.
- Every weight matmul in the model zoo goes through :func:`matmul`, the
  single dispatch point that makes the layer stack polymorphic over dense
  arrays and N:M-compressed ``sparse_infer.CompressedTensor`` leaves: the
  serving engine passes the compressed tree straight into
  ``prefill``/``decode_step`` and compressed weights route through
  ``kernels.ops.nm_spmm`` — backend-routed by ``kernels.dispatch`` to the
  Pallas kernel on TPU or the vectorized XLA path elsewhere — with no
  dense rehydration in HBM.
- Attention is implemented with an online-softmax scan over KV chunks
  (flash-attention style) so the 32k-prefill cells never materialize a
  (S, S) score matrix — this is the TPU-native memory-hierarchy adaptation
  (block lives in VMEM, HBM traffic is O(S) per query block).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.sparse_infer.compress import CompressedTensor


# ---------------------------------------------------------------------------
# the weight-matmul dispatch point (dense | N:M-compressed)
# ---------------------------------------------------------------------------

Weight = Union[jnp.ndarray, CompressedTensor]


def matmul(x: jnp.ndarray, w: Weight) -> jnp.ndarray:
    """``y = x @ w`` for a dense or N:M-compressed weight.

    Dense arrays use the native matmul (batched over leading dims for
    stacked ``(E, in, out)`` expert / layer weights). ``CompressedTensor``
    leaves route through ``kernels.ops.nm_spmm``, which streams the
    compressed ``(values, indices)`` pair and never materializes the dense
    weight in HBM (Pallas on TPU; jnp reference elsewhere).
    """
    if isinstance(w, CompressedTensor):
        return _compressed_matmul(x, w)
    return x @ w


def _compressed_matmul(x: jnp.ndarray, w: CompressedTensor) -> jnp.ndarray:
    v, idx = w.values, w.indices
    # groups must run along the contraction axis (axis -2 of the weight)
    assert w.group_axis % v.ndim == v.ndim - 2, (w.group_axis, v.shape)
    o_true = w.out_features  # strips compress-time MXU alignment columns
    if v.ndim == 2:
        lead = x.shape[:-1]
        y = kernel_ops.nm_spmm(
            x.reshape(-1, x.shape[-1]), v, idx, w.n, w.m, o_true=o_true,
            shards=w.rshards,
        )
        return y.reshape(lead + (o_true,))
    if v.ndim == 3 and x.ndim == 3:
        # stacked weights (experts (E, in, out) / scan blocks): map the
        # 2-D kernel over the leading axis.  shards stays 1 — vmap of a
        # shard_map body is unsupported, so EP stacks keep the GSPMD path
        return jax.vmap(
            lambda xe, ve, ie: kernel_ops.nm_spmm(
                xe, ve, ie, w.n, w.m, o_true=o_true
            )
        )(x, v, idx)
    raise ValueError(
        f"unsupported compressed matmul: x {x.shape} @ values {v.shape}"
    )


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


def layernorm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    sections=(2, 3, 3),
    theta: float = 10000.0,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the head dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, D); positions: (B, S, 3) int32 (temporal, height, width).
    ``sections`` are the relative shares of D/2 per stream (Qwen2-VL uses
    16/24/24 of 64 — ratio 2:3:3).
    """
    d = x.shape[-1]
    half = d // 2
    tot = sum(sections)
    splits = [half * s // tot for s in sections]
    splits[-1] = half - sum(splits[:-1])
    freqs = rope_freqs(d, theta)  # (half,)
    # build per-frequency position source: first splits[0] freqs follow t, etc.
    pos_idx = jnp.concatenate(
        [jnp.full((n,), i, jnp.int32) for i, n in enumerate(splits)]
    )  # (half,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(pos_idx, positions.shape[:2] + (half,)).astype(jnp.int32) * 0
        + pos_idx[None, None, :],
        axis=-1,
    )  # (B, S, half)
    ang = pos * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _gqa_expand(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """Reshape q (B,S,H,D) -> (B,S,n_kv,H/n_kv,D) for grouped attention."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window (local) attention
    q_offset=0,  # position of q[0] within the kv sequence: scalar or (B,)
    kv_valid_from=0,  # first valid kv slot: scalar or (B,)
    chunk: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention, scanning over KV chunks (flash style).

    Never materializes more than a (Sq, chunk) score block per (batch, head),
    which is what makes the 32k-prefill dry-run cells fit. GQA is handled by
    grouping query heads over each KV head.  ``q_offset`` may be a per-row
    ``(B,)`` vector — batched chunked prefill runs every chunking lane's
    chunk in one call, each at its own position in its own sequence.
    ``kv_valid_from`` masks leading kv slots (per-row or scalar): a windowed
    chunk view early in a sequence pads its left edge with out-of-range
    gathers, which must not attend.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    qg = _gqa_expand(q, hkv).astype(jnp.float32)  # (B,Sq,Hkv,G,D)
    scale = d**-0.5

    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hkv, d)
    vc = v.reshape(b, n_chunks, chunk, hkv, d)

    off = jnp.asarray(q_offset)
    off = off.reshape(-1, 1) if off.ndim else off[None, None]  # (B|1, 1)
    q_pos = off + jnp.arange(sq)[None, :]  # (B|1, Sq)
    vf = jnp.asarray(kv_valid_from)
    vf = vf.reshape(-1, 1) if vf.ndim else vf[None, None]  # (B|1, 1)

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        kb, vb, c_idx = inputs  # (B,chunk,Hkv,D) x2, scalar
        kv_pos = c_idx * chunk + jnp.arange(chunk)  # (chunk,)
        s = (
            jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(jnp.float32)) * scale
        )  # (B,Hkv,G,Sq,chunk)
        if causal:
            mask = kv_pos[None, None, :] <= q_pos[:, :, None]  # (B|1,Sq,chunk)
        else:
            mask = jnp.ones((1, sq, chunk), bool)
        if window is not None:
            mask = mask & (kv_pos[None, None, :] > q_pos[:, :, None] - window)
        mask = mask & (kv_pos[None, None, :] >= vf[:, :, None])
        mask = mask & (kv_pos[None, None, :] < sk)  # padding
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isinf(m_prev), -jnp.inf, m_prev) - m_safe)
        corr = jnp.where(jnp.isinf(m_prev), 0.0, corr)
        l_new = corr * l_prev + jnp.sum(p, axis=-1)
        acc = corr[..., None] * acc + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(n_chunks),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,Sq,D)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    cache_len,  # (B,) or scalar int32: valid prefix length
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token attention over a KV cache (dense — decode is
    bandwidth-bound, not memory-capacity-bound, so no chunking needed)."""
    b, s, hkv, d = k_cache.shape
    h = q.shape[2]
    g = h // hkv
    qg = _gqa_expand(q, hkv).astype(jnp.float32)[:, 0]  # (B,Hkv,G,D)
    scale = d**-0.5
    scores = (
        jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32)) * scale
    )  # (B,Hkv,G,S)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(jnp.asarray(cache_len), (-1, 1))  # (B,S)
    if window is not None:
        valid = valid & (
            pos[None, :] >= jnp.reshape(jnp.asarray(cache_len), (-1, 1)) - window
        )
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_mlp(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    """p: {gate: (d, f), up: (d, f), down: (f, d)}"""
    gate = jax.nn.silu(matmul(x, p["w_gate"]).astype(jnp.float32))
    up = matmul(x, p["w_up"]).astype(jnp.float32)
    return matmul((gate * up).astype(x.dtype), p["w_down"])


def gelu_mlp(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    """p: {w_fc: (d, f), w_proj: (f, d)} (+ optional biases)"""
    h = matmul(x, p["w_fc"])
    if "b_fc" in p:
        h = h + p["b_fc"]
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    y = matmul(h, p["w_proj"])
    if "b_proj" in p:
        y = y + p["b_proj"]
    return y
