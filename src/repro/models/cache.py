"""Cache layouts: the seam between decode math and KV-cache storage.

The model's decode/prefill math is layout-agnostic: every read or write of
an attention (or MLA-latent) cache entry goes through one of the two
``CacheLayout`` implementations below, so the same ``decode_step`` serves

- :class:`SlabLayout` — the contiguous ``(B, max_len, ...)`` per-lane slab
  the training/tests path has always used, and
- :class:`PagedLayout` — a block-granular pool: each layer owns a
  ``(num_pages, page_size, ...)`` array, and per-request *page tables*
  (``(B, pages)`` int32, device-resident, updated host-side by
  ``repro.serving.kv_pool.PagedKVPool``) map logical token positions to
  physical pages.  Reads gather the logical view through the table; writes
  scatter one token into its page.  Unmapped table slots hold the sentinel
  ``num_pages`` — out of bounds, so scatters drop and gathers clip to
  garbage that the attention length-mask zeroes exactly.

Logical addressing is **append-only** in both layouts, which is what makes
paged decode bit-identical to slab decode: the gathered paged view lists
entries in the same oldest-to-newest order the slab stores them, and the
extra masked positions contribute exact zeros to the softmax.

Sliding-window layers use a *modular* page table of
``ceil((window + lookahead - 1)/page_size) + 1`` slots: position ``p``
lives in table slot ``(p // page_size) % n_slots``, so as the window
slides past a page boundary the expired page's slot is reclaimed and the
page itself is returned to the free list (whole-page eviction).  The
gathered view is rebuilt in logical order from the lane's rolling window,
matching the slab's per-lane ``jnp.roll`` content element for element.

``lookahead`` is the number of decode steps one fused dispatch may take
without host intervention (the engine's ``steps_per_dispatch``): the host
pre-maps every page those steps will write *before* the dispatch, and the
extra modular slots guarantee a pre-mapped future page never lands in the
slot of a page still inside some iteration's live window.  (Pre-mapped
future pages are invisible to reads: full-table slots fail ``base <
length`` and window slots fail ``base + page_size > length - window`` in
both the Pallas kernel and the gathered reference, so they only become
visible once the scan actually writes them.)

SSM / RG-LRU states are O(1) per lane and are *not* paged — they stay
``(B, ...)`` slot-indexed under both layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Contiguous ``(B, max_len, ...)`` per-lane cache (training/tests)."""

    max_len: int = 0  # only needed for allocation, not for read/write

    kind = "slab"

    # -- allocation ---------------------------------------------------------

    def attn_alloc(self, batch: int, window: Optional[int], n_kv: int,
                   hd: int, dtype) -> dict:
        s = self.max_len if window is None else min(self.max_len, window)
        shp = (batch, s, n_kv, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

    def mla_alloc(self, batch: int, kv_lora: int, rope_dim: int, dtype) -> dict:
        return {
            "ckv": jnp.zeros((batch, self.max_len, kv_lora), dtype),
            "krope": jnp.zeros((batch, self.max_len, rope_dim), dtype),
        }

    def tables(self, batch: int) -> Optional[dict]:
        return None

    # -- decode-step read/write --------------------------------------------

    def attn_rw(self, c: dict, k_new, v_new, pos, tables, window):
        """Write one token at ``pos`` per lane; return the logical view.

        ``k_new``/``v_new``: (B, n_kv, hd).  Returns
        ``(k_view, v_view, new_entry)`` where the views are ``(B, S, ...)``
        in oldest-to-newest logical order.
        """
        b = k_new.shape[0]
        s_cache = c["k"].shape[1]
        if window is not None and window <= s_cache:
            # ring-free rolling window, gated per lane: continuous batching
            # gives every lane its own position
            full = pos >= s_cache  # (B,)
            kc = jnp.where(
                full[:, None, None, None], jnp.roll(c["k"], -1, axis=1), c["k"]
            )
            vc = jnp.where(
                full[:, None, None, None], jnp.roll(c["v"], -1, axis=1), c["v"]
            )
            slot = jnp.minimum(pos, s_cache - 1)
        else:
            kc, vc = c["k"], c["v"]
            slot = pos
        bidx = jnp.arange(b)
        kc = kc.at[bidx, slot].set(k_new)
        vc = vc.at[bidx, slot].set(v_new)
        return kc, vc, {"k": kc, "v": vc}

    def mla_rw(self, c: dict, ckv_new, krope_new, pos, tables):
        b = ckv_new.shape[0]
        bidx = jnp.arange(b)
        ckv = c["ckv"].at[bidx, pos].set(ckv_new)
        krope = c["krope"].at[bidx, pos].set(krope_new)
        return ckv, krope, {"ckv": ckv, "krope": krope}

    # -- batched prefill writes --------------------------------------------

    def attn_write_rows(self, c: dict, k_rows, v_rows, lanes, lens,
                        tables, window):
        """Write freshly prefilled rows into lanes (sentinel lanes drop).

        ``k_rows``: (N, Lp, n_kv, hd) — the full (possibly padded) prompt K;
        row ``r`` holds valid entries at positions ``< lens[r]``.

        Rows shorter than the slab scatter *by position index* (invalid
        positions route out of bounds and drop) instead of padding to the
        slab length and overwriting whole rows: functionally identical —
        slots ``>= len`` are dead under the attention length mask, exactly
        like the paged layout's unwritten slots — but it never runs
        ``jnp.pad`` + full-row ``set`` over a sequence-sharded slab, a
        pattern the XLA partitioner handles with an "involuntary full
        rematerialization" that was observed to *miscompile* (wrong
        values) on seq-sharded windowed caches (CPU backend, jax 0.4.37).
        """
        s = c["k"].shape[1]
        lp = k_rows.shape[1]
        if s < lp:
            # windowed slab shorter than the padded prompt: keep each row's
            # last min(len, s) entries, oldest first (the slab's rolled order)
            j = jnp.arange(s)[None, :]
            start = jnp.maximum(0, lens - s)[:, None]
            idx = jnp.clip(start + j, 0, lp - 1)
            k_rows = jnp.take_along_axis(k_rows, idx[..., None, None], axis=1)
            v_rows = jnp.take_along_axis(v_rows, idx[..., None, None], axis=1)
            return {
                "k": c["k"].at[lanes].set(
                    k_rows.astype(c["k"].dtype), mode="drop"
                ),
                "v": c["v"].at[lanes].set(
                    v_rows.astype(c["v"].dtype), mode="drop"
                ),
            }
        j = jnp.arange(lp)[None, :]  # (1, Lp)
        idx = jnp.where(j < lens[:, None], j, s)  # invalid rows drop OOB
        return {
            "k": c["k"].at[lanes[:, None], idx].set(
                k_rows.astype(c["k"].dtype), mode="drop"
            ),
            "v": c["v"].at[lanes[:, None], idx].set(
                v_rows.astype(c["v"].dtype), mode="drop"
            ),
        }

    def mla_write_rows(self, c: dict, ckv_rows, krope_rows, lanes, lens, tables):
        s = c["ckv"].shape[1]
        lp = ckv_rows.shape[1]
        j = jnp.arange(lp)[None, :]
        idx = jnp.where(j < lens[:, None], j, s)  # invalid rows drop OOB
        return {
            "ckv": c["ckv"].at[lanes[:, None], idx].set(
                ckv_rows.astype(c["ckv"].dtype), mode="drop"
            ),
            "krope": c["krope"].at[lanes[:, None], idx].set(
                krope_rows.astype(c["krope"].dtype), mode="drop"
            ),
        }

    # -- chunked-prefill writes / views ------------------------------------
    #
    # One prompt chunk per chunking lane, batched: row ``r``'s entries
    # ``i < lengths[r]`` land at positions ``starts[r] + i`` of lane
    # ``lanes[r]`` (a lane index >= the batch size marks a padding row and
    # drops).  Only non-windowed slabs support chunking (the engine gates
    # slab sliding-window archs off the chunked path; the paged layout
    # chunks windowed layers through the modular table below).

    def attn_write_chunk(self, c: dict, k_rows, v_rows, lanes, starts,
                         lengths, tables, window=None):
        """k_rows/v_rows: (L, C, n_kv, hd); lanes/starts/lengths: (L,)."""
        s = c["k"].shape[1]
        i = jnp.arange(k_rows.shape[1])[None, :]  # (1, C)
        # pad rows (i >= length) drop out of bounds
        idx = jnp.where(i < lengths[:, None], starts[:, None] + i, s)
        return {
            "k": c["k"].at[lanes[:, None], idx].set(
                k_rows.astype(c["k"].dtype), mode="drop"
            ),
            "v": c["v"].at[lanes[:, None], idx].set(
                v_rows.astype(c["v"].dtype), mode="drop"
            ),
        }

    def attn_chunk_view(self, c: dict, lanes, tables):
        """(L, S, n_kv, hd) logical views (the slab rows themselves;
        sentinel lanes clip to the last row — garbage the caller masks)."""
        take = jnp.clip(lanes, 0, c["k"].shape[0] - 1)
        return c["k"][take], c["v"][take]

    def mla_write_chunk(self, c: dict, ckv_rows, krope_rows, lanes, starts,
                        lengths, tables):
        s = c["ckv"].shape[1]
        i = jnp.arange(ckv_rows.shape[1])[None, :]
        idx = jnp.where(i < lengths[:, None], starts[:, None] + i, s)
        return {
            "ckv": c["ckv"].at[lanes[:, None], idx].set(
                ckv_rows.astype(c["ckv"].dtype), mode="drop"
            ),
            "krope": c["krope"].at[lanes[:, None], idx].set(
                krope_rows.astype(c["krope"].dtype), mode="drop"
            ),
        }

    def mla_chunk_view(self, c: dict, lanes, tables):
        take = jnp.clip(lanes, 0, c["ckv"].shape[0] - 1)
        return c["ckv"][take], c["krope"][take]


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Block-granular paged cache behind per-request page tables.

    ``max_len`` is the *logical* per-request capacity (the full-attention
    page-table width is ``ceil(max_len / page_size)``); physical capacity
    is ``num_pages`` pages shared by all lanes — one page id is backed in
    every paged layer's pool, so "allocating a page" reserves a token block
    across the whole model at once.

    **Truncate-aware views.**  Every read path — the decode views
    (``attn_rw`` / the ``paged_attn`` kernel's length operand), the chunk
    views (``attn_chunk_view`` and the MLA analogues) — masks by the
    lane's live length (``cache["len"]`` / the attention length mask),
    never by what a page physically holds.  Rewinding ``cache["len"]``
    therefore *is* a truncation: stale KV past the new length (e.g. a
    speculative draft tail the verifier rejected) is unreachable, and the
    host pool can release the over-reserved pages
    (``PagedKVPool.rollback``) — their table slots return to the
    out-of-bounds sentinel, which scatters drop and gathers clip to a
    masked row.  No page contents are ever scrubbed on rollback.
    """

    page_size: int
    num_pages: int
    max_len: int
    win: int = 0  # min(max_len, local_window) when the arch has windowed attn
    has_full: bool = True  # any non-windowed attn / MLA layer present
    lookahead: int = 1  # decode steps one dispatch may take (pages pre-mapped)
    # number of mesh shards the physical pool is partitioned across (the
    # model-axis size of a mesh-native engine).  >1 routes paged attention
    # to the shard_map wrapper when one is registered and the pool splits
    # evenly (kernels.sharded: per-shard table remap + Pallas grid walk +
    # psum'd flash-stat combine); the GSPMD-partitionable gathered path
    # remains the correctness backstop (see kernels.dispatch).
    shards: int = 1
    # int8 pool arrays: every paged KV leaf stores symmetric int8 with one
    # scale per (page, slot) — ``<leaf>_scale`` arrays of shape
    # ``(num_pages, page_size)`` living beside the pool, sharded on the
    # same pages axis.  Scales are *stored* f16 (so small-feature smoke
    # pools still beat the 2x HBM bar) but every producer/consumer does
    # the scale math in f32: ``_quant`` rounds the scale through f16
    # before dividing, and all dequant sites upcast.  Writes quantize on
    # scatter (per-token absmax over the head/feature dims); reads — the
    # Pallas kernel, the gathered XLA twin, and every reference/chunk
    # view — dequantize per page under the same math, so streams match fp
    # pages to quantization tolerance (and HBM per cached token drops ~4x
    # vs f32).
    quant: bool = False

    kind = "paged"

    # quantized clamp floor: keeps all-zero pages (and true zero tokens)
    # from dividing by zero.  Must survive the f16 storage round-trip as a
    # nonzero *normal* (f16 min normal ~6.1e-5); binds only for tokens
    # with absmax < 127*_QEPS ~ 0.013, where the absolute error it adds
    # (<= _QEPS/2) is far below quantization noise.
    _QEPS = 1e-4

    @property
    def pages_full(self) -> int:
        return cdiv(self.max_len, self.page_size) if self.has_full else 0

    @property
    def pages_win(self) -> int:
        # +lookahead-1: room to pre-map every page a K-step dispatch writes
        # without a modular slot collision with a still-live page (see
        # module docstring)
        if not self.win:
            return 0
        return cdiv(self.win + max(self.lookahead, 1) - 1, self.page_size) + 1

    @property
    def sentinel(self) -> int:
        return self.num_pages  # out of bounds: scatters drop, gathers clip

    # -- allocation ---------------------------------------------------------

    def attn_alloc(self, batch: int, window: Optional[int], n_kv: int,
                   hd: int, dtype) -> dict:
        shp = (self.num_pages, self.page_size, n_kv, hd)
        if self.quant:
            sc = (self.num_pages, self.page_size)
            return {
                "k": jnp.zeros(shp, jnp.int8),
                "v": jnp.zeros(shp, jnp.int8),
                "k_scale": jnp.zeros(sc, jnp.float16),
                "v_scale": jnp.zeros(sc, jnp.float16),
            }
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

    def mla_alloc(self, batch: int, kv_lora: int, rope_dim: int, dtype) -> dict:
        shp = (self.num_pages, self.page_size)
        if self.quant:
            return {
                "ckv": jnp.zeros(shp + (kv_lora,), jnp.int8),
                "krope": jnp.zeros(shp + (rope_dim,), jnp.int8),
                "ckv_scale": jnp.zeros(shp, jnp.float16),
                "krope_scale": jnp.zeros(shp, jnp.float16),
            }
        return {
            "ckv": jnp.zeros(shp + (kv_lora,), dtype),
            "krope": jnp.zeros(shp + (rope_dim,), dtype),
        }

    # -- int8 page quantization --------------------------------------------

    def _quant(self, x, lead: int):
        """Quantize ``x`` per token: absmax over dims ``lead..`` → scale."""
        xf = x.astype(jnp.float32)
        red = tuple(range(lead, x.ndim))
        scale = jnp.maximum(
            jnp.max(jnp.abs(xf), axis=red) / 127.0, self._QEPS
        )
        # round-trip through the f16 storage dtype so quantization divides
        # by exactly the scale every dequant site will multiply back
        scale = scale.astype(jnp.float16).astype(jnp.float32)
        q = jnp.round(
            xf / scale.reshape(scale.shape + (1,) * (x.ndim - lead))
        ).astype(jnp.int8)
        return q, scale.astype(jnp.float16)

    @staticmethod
    def dequant(q, scale):
        """Inverse of :meth:`_quant`: codes x per-token scales → f32."""
        return q.astype(jnp.float32) * scale.astype(jnp.float32).reshape(
            scale.shape + (1,) * (q.ndim - scale.ndim)
        )

    def tables(self, batch: int) -> Optional[dict]:
        t = {}
        if self.pages_full:
            t["full"] = jnp.full((batch, self.pages_full), self.sentinel, jnp.int32)
        if self.pages_win:
            t["win"] = jnp.full((batch, self.pages_win), self.sentinel, jnp.int32)
        return t or None

    def _windowed(self, window: Optional[int]) -> bool:
        return window is not None and window <= self.max_len

    # -- kernel-facing geometry --------------------------------------------
    #
    # The Pallas paged-attention fast path (kernels.paged_attn, routed by
    # kernels.dispatch) consumes the raw pool + page table; these helpers
    # hand it the table and its modular-window parameters without the
    # caller re-deriving layout internals.

    def table_key(self, window: Optional[int]) -> str:
        return "win" if self._windowed(window) else "full"

    def view_window(self, window: Optional[int]) -> int:
        """Live-window width for the kernel (0 = full / append-only)."""
        return min(self.max_len, window) if self._windowed(window) else 0

    def _view_index(self, pos, window):
        """(abs positions (B, S_view), table-slot indices (B, S_view), table key)."""
        ps = self.page_size
        if self._windowed(window):
            s_view = min(self.max_len, window)
            start = jnp.maximum(0, pos - s_view + 1)  # (B,)
            a = start[:, None] + jnp.arange(s_view)[None, :]
            return a, (a // ps) % self.pages_win, "win"
        s_view = self.pages_full * ps
        a = jnp.broadcast_to(jnp.arange(s_view)[None, :], (pos.shape[0], s_view))
        return a, a // ps, "full"

    def _write_slot(self, pt, pos, window):
        """Flat pool index of each lane's write at ``pos`` (sentinel drops)."""
        ps = self.page_size
        page = pos // ps
        if self._windowed(window):
            page = page % self.pages_win
        bidx = jnp.arange(pos.shape[0])
        phys = pt[bidx, page]  # (B,) — sentinel when unmapped (idle lane)
        return phys * ps + pos % ps

    def _gather(self, flat, pt, a, tslot):
        phys = jnp.take_along_axis(pt, tslot, axis=1)  # (B, S_view)
        idx = phys * self.page_size + a % self.page_size
        return jnp.take(flat, idx, axis=0, mode="clip")

    def _scatter(self, c: dict, entries: dict, widx) -> dict:
        """Scatter new tokens into the flat pools at ``widx`` (sentinel
        slots drop); the single write seam shared by decode, batched
        prefill, and chunked prefill.

        ``entries``: leaf name → new values whose leading dims flatten to
        match ``widx``.  Under ``quant`` each token quantizes on the way
        in and its scale scatters into the ``<name>_scale`` plane at the
        same flat slot.  Returns ``c`` with the touched leaves replaced
        (scale planes included), so callers can hand the dict straight
        back as the layer's new cache."""
        out = dict(c)
        for name, x in entries.items():
            flat = c[name].reshape((-1,) + c[name].shape[2:])
            if self.quant:
                q, s = self._quant(x, 1)
                flat = flat.at[widx].set(q, mode="drop")
                sname = name + "_scale"
                out[sname] = (
                    c[sname].reshape(-1).at[widx].set(s, mode="drop")
                ).reshape(c[sname].shape)
            else:
                flat = flat.at[widx].set(x.astype(c[name].dtype), mode="drop")
            out[name] = flat.reshape(c[name].shape)
        return out

    def _gather_view(self, c: dict, name: str, pt, a, tslot):
        """Gathered logical view of one leaf, dequantized under ``quant``."""
        flat = c[name].reshape((-1,) + c[name].shape[2:])
        v = self._gather(flat, pt, a, tslot)
        if self.quant:
            s = self._gather(c[name + "_scale"].reshape(-1), pt, a, tslot)
            v = self.dequant(v, s)
        return v

    # -- decode-step read/write --------------------------------------------

    def attn_write(self, c: dict, k_new, v_new, pos, tables, window) -> dict:
        """Scatter one token per lane into its page; no logical view built.

        This is the whole device-side cache mutation of the paged fast
        path: the Pallas kernel reads the pool through the table directly,
        so — unlike :meth:`attn_rw` — no contiguous ``(B, S, ...)`` view is
        ever materialized.
        """
        pt = tables[self.table_key(window)]
        widx = self._write_slot(pt, pos, window)
        return self._scatter(c, {"k": k_new, "v": v_new}, widx)

    def mla_write(self, c: dict, ckv_new, krope_new, pos, tables) -> dict:
        """Latent-cache analogue of :meth:`attn_write` (append-only table)."""
        pt = tables["full"]
        widx = self._write_slot(pt, pos, None)
        return self._scatter(c, {"ckv": ckv_new, "krope": krope_new}, widx)

    def attn_rw(self, c: dict, k_new, v_new, pos, tables, window):
        """Write + *gathered* logical view — the parity reference path
        (bit-identical to the slab; see module docstring).  Under ``quant``
        the view dequantizes what the write just stored — every read,
        including the current token's, sees the int8-rounded values, same
        as the kernel fast path."""
        new = self.attn_write(c, k_new, v_new, pos, tables, window)
        a, tslot, key = self._view_index(pos, window)
        pt = tables[key]
        k_view = self._gather_view(new, "k", pt, a, tslot)
        v_view = self._gather_view(new, "v", pt, a, tslot)
        return k_view, v_view, new

    def mla_rw(self, c: dict, ckv_new, krope_new, pos, tables):
        new = self.mla_write(c, ckv_new, krope_new, pos, tables)
        a, tslot, key = self._view_index(pos, None)
        pt = tables[key]
        ckv_view = self._gather_view(new, "ckv", pt, a, tslot)
        krope_view = self._gather_view(new, "krope", pt, a, tslot)
        return ckv_view, krope_view, new

    # -- batched prefill writes --------------------------------------------

    def _row_write_idx(self, lanes, lens, lp, tables, window):
        """Flat pool indices (N, Lp) for prompt rows (invalid → sentinel)."""
        ps = self.page_size
        a = jnp.broadcast_to(jnp.arange(lp)[None, :], (lens.shape[0], lp))
        valid = a < lens[:, None]
        if self._windowed(window):
            s_view = min(self.max_len, window)
            valid = valid & (a >= jnp.maximum(0, lens - s_view)[:, None])
            tslot = (a // ps) % self.pages_win
            pt = tables["win"]
        else:
            tslot = a // ps
            pt = tables["full"]
        rows_pt = jnp.take(pt, lanes, axis=0, mode="clip")  # (N, table_w)
        phys = jnp.take_along_axis(rows_pt, tslot, axis=1)  # (N, Lp)
        # padding rows carry a sentinel lane: their table row gathers as
        # clip-garbage, but valid is all-False there (lens == 0)
        valid = valid & (lanes < pt.shape[0])[:, None]
        return jnp.where(valid, phys * ps + a % ps, self.num_pages * ps)

    def attn_write_rows(self, c: dict, k_rows, v_rows, lanes, lens,
                        tables, window):
        lp = k_rows.shape[1]
        widx = self._row_write_idx(lanes, lens, lp, tables, window).reshape(-1)
        return self._scatter(
            c,
            {
                "k": k_rows.reshape((-1,) + k_rows.shape[2:]),
                "v": v_rows.reshape((-1,) + v_rows.shape[2:]),
            },
            widx,
        )

    def mla_write_rows(self, c: dict, ckv_rows, krope_rows, lanes, lens, tables):
        lp = ckv_rows.shape[1]
        widx = self._row_write_idx(lanes, lens, lp, tables, None).reshape(-1)
        return self._scatter(
            c,
            {
                "ckv": ckv_rows.reshape((-1,) + ckv_rows.shape[2:]),
                "krope": krope_rows.reshape((-1,) + krope_rows.shape[2:]),
            },
            widx,
        )

    # -- chunked-prefill writes / views ------------------------------------
    #
    # One prompt chunk per chunking lane, batched, through each lane's
    # table row.  Non-windowed layers chunk through the *full*
    # (append-only) table: all of a chunk's pages were mapped at admission
    # (``alloc_prefill`` covers the whole prompt), so every valid row has
    # a physical slot.  Windowed layers chunk through the *modular* ``win``
    # table: the engine maps each chunk's pages just before its dispatch
    # (``ensure_steps(lane, start, csz)``, which also evicts pages wholly
    # before ``start - win + 1``), so a chunk only ever needs
    # ``win + csz - 1`` live positions — the exact span
    # :meth:`attn_chunk_view_win` gathers.  Pad rows (``i >= lengths[r]``
    # or a sentinel lane) route to the sentinel.

    def _chunk_write_idx(self, lanes, starts, lengths, csz, tables,
                         window=None):
        ps = self.page_size
        i = jnp.arange(csz)[None, :]  # (1, C)
        pos = starts[:, None] + i  # (L, C)
        if self._windowed(window):
            pt = tables["win"]
            tslot = (pos // ps) % self.pages_win
        else:
            pt = tables["full"]
            tslot = jnp.clip(pos // ps, 0, self.pages_full - 1)
        rows = jnp.take(pt, lanes, axis=0, mode="clip")
        phys = jnp.take_along_axis(rows, tslot, axis=1)  # (L, C)
        valid = (i < lengths[:, None]) & (lanes < pt.shape[0])[:, None]
        return jnp.where(valid, phys * ps + pos % ps, self.num_pages * ps)

    def attn_write_chunk(self, c: dict, k_rows, v_rows, lanes, starts,
                         lengths, tables, window=None):
        widx = self._chunk_write_idx(
            lanes, starts, lengths, k_rows.shape[1], tables, window
        ).reshape(-1)
        return self._scatter(
            c,
            {
                "k": k_rows.reshape((-1,) + k_rows.shape[2:]),
                "v": v_rows.reshape((-1,) + v_rows.shape[2:]),
            },
            widx,
        )

    def _chunk_gather(self, flat, lanes, tables):
        ps = self.page_size
        a = jnp.arange(self.pages_full * ps)  # (S,)
        rows = jnp.take(tables["full"], lanes, axis=0, mode="clip")
        phys = rows[:, a // ps]  # (L, S); sentinel slots -> clip garbage
        return jnp.take(flat, phys * ps + a % ps, axis=0, mode="clip")

    def _chunk_view(self, c: dict, name: str, lanes, tables):
        flat = c[name].reshape((-1,) + c[name].shape[2:])
        v = self._chunk_gather(flat, lanes, tables)
        if self.quant:
            s = self._chunk_gather(c[name + "_scale"].reshape(-1), lanes, tables)
            v = self.dequant(v, s)
        return v

    def attn_chunk_view(self, c: dict, lanes, tables):
        return (
            self._chunk_view(c, "k", lanes, tables),
            self._chunk_view(c, "v", lanes, tables),
        )

    def attn_chunk_view_win(self, c: dict, lanes, starts, csz: int,
                            window: int, tables):
        """Windowed chunk view through the modular table.

        Returns ``(k_view, v_view)`` of static width ``win + csz - 1``:
        the logical positions ``[starts - win + 1, starts + csz - 1]`` —
        everything the chunk's last token can attend under a ``win``-wide
        sliding window, ending at the chunk's final position.  Early in a
        sequence the left edge dips below position 0; those slots gather
        clip-garbage and the caller masks them via ``chunked_attention``'s
        ``kv_valid_from = max(0, -(starts - win + 1))``.  Every in-range
        position is still mapped: the engine's per-chunk ``ensure_steps``
        evicts only pages wholly before ``starts - win + 1``.
        """
        ps = self.page_size
        win = min(self.max_len, window)
        s_view = win + csz - 1
        vbase = starts - win + 1  # (L,), may be negative
        a = vbase[:, None] + jnp.arange(s_view)[None, :]  # (L, S_v)
        an = jnp.maximum(a, 0)
        pt = tables["win"]
        rows = jnp.take(pt, lanes, axis=0, mode="clip")
        phys = jnp.take_along_axis(rows, (an // ps) % self.pages_win, axis=1)
        valid = (a >= 0) & (lanes < pt.shape[0])[:, None]
        idx = jnp.where(valid, phys * ps + an % ps, self.num_pages * ps)

        def g(name):
            flat = c[name].reshape((-1,) + c[name].shape[2:])
            v = jnp.take(flat, idx, axis=0, mode="clip")
            if self.quant:
                s = jnp.take(
                    c[name + "_scale"].reshape(-1), idx, axis=0, mode="clip"
                )
                v = self.dequant(v, s)
            return v

        return g("k"), g("v")

    def mla_write_chunk(self, c: dict, ckv_rows, krope_rows, lanes, starts,
                        lengths, tables):
        widx = self._chunk_write_idx(
            lanes, starts, lengths, ckv_rows.shape[1], tables
        ).reshape(-1)
        return self._scatter(
            c,
            {
                "ckv": ckv_rows.reshape((-1,) + ckv_rows.shape[2:]),
                "krope": krope_rows.reshape((-1,) + krope_rows.shape[2:]),
            },
            widx,
        )

    def mla_chunk_view(self, c: dict, lanes, tables):
        return (
            self._chunk_view(c, "ckv", lanes, tables),
            self._chunk_view(c, "krope", lanes, tables),
        )


CacheLayout = (SlabLayout, PagedLayout)  # for isinstance checks


def paged_layout_for(
    cfg, max_len: int, *, page_size: int, num_pages: int, lookahead: int = 1,
    shards: int = 1, quant: bool = False,
) -> PagedLayout:
    """Derive the PagedLayout an arch needs at a given logical capacity.

    A layer is *windowed* iff ``local_window <= max_len`` — the same
    condition under which the slab rolls — otherwise its window never
    slides within the logical capacity and it pages like full attention.
    ``lookahead`` is the engine's ``steps_per_dispatch`` — how many decode
    writes one fused dispatch performs before the host touches the tables
    again (sizes the modular window table; see :class:`PagedLayout`).
    ``shards`` records how many mesh shards partition the physical pool
    (kernel-route gating; see :class:`PagedLayout`).
    """
    from repro.models.model import _block_mixer_mlp, layer_plan

    plan = layer_plan(cfg)
    kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
    mixers = {_block_mixer_mlp(k, cfg)[0] for k in kinds}
    windowed = (
        "attn" in mixers
        and cfg.local_window is not None
        and cfg.local_window <= max_len
    )
    has_full = "mla" in mixers or ("attn" in mixers and not windowed)
    win = min(max_len, cfg.local_window) if windowed else 0
    return PagedLayout(
        page_size=page_size, num_pages=num_pages, max_len=max_len,
        win=win, has_full=has_full, lookahead=max(1, lookahead),
        shards=max(1, shards), quant=quant,
    )
