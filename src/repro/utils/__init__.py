from repro.utils.tree import (
    tree_paths,
    tree_map_with_name,
    global_norm,
    tree_size,
    tree_zeros_like,
    tree_add,
    tree_scale,
)
