"""Post-compile HLO analysis: collective-byte accounting for the roofline.

``cost_analysis`` has FLOPs and bytes-accessed but no collective traffic, so
we parse the optimized HLO text and sum operand sizes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
as the assignment prescribes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %x = bf16[8,128]{1,0} all-reduce(...)   /  (f32[4], bf16[2,2]) all-to-all
_OP_RE = re.compile(
    r"=\s*(?P<out>\(?[a-z0-9\[\],{}\s]*\)?)\s*(?P<op>"
    + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by kind.

    Uses the *result* shape of each collective as its payload proxy (the
    '-done' halves of async pairs are skipped to avoid double counting).
    """
    per_kind: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # async completion: counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("op")
        b = _shape_bytes(m.group("out"))
        per_kind[kind] += b
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind": dict(per_kind), "counts": dict(counts)}


def count_ops(hlo_text: str, name: str) -> int:
    return len(re.findall(rf"\b{re.escape(name)}\(", hlo_text))


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    return out


def cost_analysis_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
