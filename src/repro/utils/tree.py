"""Pytree utilities used across the framework.

Parameter trees in this framework are nested dicts of jnp arrays. Leaf
*names* are '/'-joined dict-key paths (e.g. ``"blocks/attn/wq"``); sparsity
configs, sharding rules and checkpoints all key off these names.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree: Any) -> list[str]:
    """Return the '/'-joined name of every leaf, in tree order."""
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [_path_str(p) for p, _ in leaves]


def tree_map_with_name(fn: Callable[[str, Any], Any], tree: Any, *rest: Any) -> Any:
    """``tree_map`` where ``fn(name, leaf, *rest_leaves)`` also sees the leaf name."""

    def wrapper(path, leaf, *others):
        return fn(_path_str(path), leaf, *others)

    return jax.tree_util.tree_map_with_path(wrapper, tree, *rest)


def global_norm(tree: Any) -> jnp.ndarray:
    """L2 norm over all leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves (python int; static)."""
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree
    )


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_scale(a: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def tree_where(pred, a: Any, b: Any) -> Any:
    """Elementwise select between two trees on a scalar predicate."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)
