"""Trip-count-aware HLO cost model (FLOPs + collective bytes).

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scanned-layer models by ~the layer count (verified empirically —
a 10-iteration scan reports 1 iteration's FLOPs). This walker parses the
optimized HLO text and recursively costs the module:

- ``dot``  -> 2 * size(result) * prod(lhs contracting dims)
- collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) -> result bytes (payload proxy), by kind
- ``while`` -> trip_count (from the ``known_trip_count`` backend_config XLA
  attaches to counted loops) x cost(body)
- ``fusion`` / ``call`` / ``conditional`` -> cost of the called computations

Elementwise FLOPs are ignored (matmul-dominated models; the roofline compute
term cares about MXU work).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_elems_bytes(shape_text: str) -> tuple[int, int]:
    """(elements, bytes) summed over every array shape in the text."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _parse_dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(float))
    unknown_while: int = 0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * times
        self.unknown_while += other.unknown_while

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_FIRST_CALL_RE = re.compile(r"([a-zA-Z][\w\-]*)\(")


def _parse_op_line(line: str):
    """Return (name, shape_text, op, rest) or None.

    Robust to tuple shapes containing ``/*index=N*/`` comments and layout
    annotations — finds the first ``identifier(`` after the '=' as the op.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end() :]
    call = _FIRST_CALL_RE.search(rest)
    if not call:
        return None
    return name, rest[: call.start()], call.group(1), rest[call.end() :]
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->")


def _split_computations(hlo: str) -> tuple[dict[str, list[str]], Optional[str]]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group("name")
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _trip_count(line: str) -> Optional[int]:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    if m:
        return int(m.group(1))
    return None


def _called(line: str) -> list[str]:
    out = []
    m = re.search(r"calls=%?([\w.\-]+)", line)
    if m:
        out.append(m.group(1))
    m = re.search(r"body=%?([\w.\-]+)", line)
    if m:
        out.append(m.group(1))
    m = re.search(r"condition=%?([\w.\-]+)", line)
    if m:
        out.append(m.group(1))
    # conditional: branch_computations={%a, %b}
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    m2 = re.search(r"true_computation=%?([\w.\-]+)", line)
    if m2:
        out.append(m2.group(1))
    m2 = re.search(r"false_computation=%?([\w.\-]+)", line)
    if m2:
        out.append(m2.group(1))
    return out


def module_cost(hlo: str) -> Cost:
    comps, entry = _split_computations(hlo)
    memo: dict[str, Cost] = {}

    def shapes_table(lines: list[str]) -> dict[str, str]:
        table = {}
        for line in lines:
            parsed = _parse_op_line(line)
            if parsed:
                table[parsed[0]] = parsed[1]
        return table

    def cost_of(comp: str, stack=()) -> Cost:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in comps:
            return Cost()
        c = Cost()
        lines = comps[comp]
        table = shapes_table(lines)
        for line in lines:
            parsed = _parse_op_line(line)
            if not parsed:
                continue
            _, shape, op, args = parsed
            if op in ("dot", "dot-general"):
                out_elems, _ = _shape_elems_bytes(shape)
                lhs_m = re.search(r"\s*%([\w.\-]+)", args)
                k = 1
                cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                if lhs_m and cd and lhs_m.group(1) in table:
                    lhs_dims = _parse_dims(table[lhs_m.group(1)])
                    for d in cd.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                c.flops += 2.0 * out_elems * k
            elif op in _COLLECTIVES or any(
                op == f"{x}-start" for x in _COLLECTIVES
            ):
                base = op.replace("-start", "")
                _, byts = _shape_elems_bytes(shape)
                c.coll_bytes[base] += byts
            elif op == "while":
                tc = _trip_count(line)
                if tc is None:
                    tc = 1
                    c.unknown_while += 1
                for callee in _called(line):
                    if callee in comps:
                        # body costed tc times; condition tc times (free-ish)
                        c.add(cost_of(callee, stack + (comp,)), times=tc)
            elif op == "conditional":
                # lax.cond: one branch executes per step — model the worst
                # (max-cost) branch, not the sum (STEP's mask/no-mask cond
                # would otherwise double-count)
                branch_costs = [
                    cost_of(callee, stack + (comp,))
                    for callee in _called(line)
                    if callee in comps
                ]
                if branch_costs:
                    worst = max(
                        branch_costs,
                        key=lambda bc: bc.flops + bc.collective_total,
                    )
                    c.add(worst)
            elif op in ("fusion", "call", "custom-call", "map",
                        "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
                for callee in _called(line):
                    if callee in comps:
                        c.add(cost_of(callee, stack + (comp,)))
        memo[comp] = c
        return c

    if entry is None:
        return Cost()
    total = Cost()
    total.add(cost_of(entry))
    return total


def analyze(compiled_text: str) -> dict:
    c = module_cost(compiled_text)
    return {
        "flops": c.flops,
        "collective_bytes": dict(c.coll_bytes),
        "collective_total": c.collective_total,
        "unknown_trip_count_whiles": c.unknown_while,
    }
