"""Deployable N:M-compressed model export — the tree the serving engine runs on.

``compress_params`` converts a trained parameter tree + SparsityConfig into
a tree where every maskable leaf is replaced by a :class:`CompressedTensor`
(values + packed indices). This tree is *served directly*: the model's
matmul dispatch point (``models.layers.matmul``) recognizes compressed
leaves and routes them through ``kernels.ops.nm_spmm`` (Pallas on TPU,
jnp reference elsewhere), so ``model.prefill`` / ``model.decode_step`` and
the ``repro.serving`` engine consume the compressed form with no dense
rehydration in HBM. Weight footprint drops to ~N/M (+1 byte/kept-element of
index) — the TPU-native analogue of deploying onto Ampere Sparse Tensor
Cores (DESIGN.md §3). ``decompress_params`` remains only as a debugging /
parity-test utility.

``CompressedTensor`` is a registered pytree whose children are the two
arrays and whose (n, m, group_axis, shape) metadata is static aux data, so
compressed trees flow through ``jax.jit``, ``lax.scan`` over stacked layer
blocks, and ``jax.vmap`` without the metadata being traced.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.masking import nm_compress, nm_decompress
from repro.core.sparsity_config import SparsityConfig
from repro.utils.tree import tree_map_with_name


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)  # array fields: no __eq__
class CompressedTensor:
    """An N:M-compressed weight: kept values + uint8 in-group offsets.

    Pytree children: ``(values, indices)``. Static aux: ``(n, m, group_axis,
    shape, pad, rshards)`` — ``shape`` records the dense shape at
    construction time (for reporting; transformations like ``lax.scan``
    that slice the children leave it untouched, so derive live shapes from
    ``values`` when needed).  ``pad`` is the number of MXU-alignment
    columns appended to the *last* axis at compress time (see
    :func:`compress_params`): the kernels slice it off their result, so it
    never leaks into the math, and because it is stored in the static aux
    it survives ``lax.scan`` / ``vmap`` slicing of stacked layer blocks
    where ``shape`` goes stale.  ``rshards`` is the number of model-axis
    mesh shards partitioning the group (reduction) axis when the leaf is
    reduction-TP'd — 1 everywhere except trees stamped by
    ``distributed.compressed_pspecs.annotate_reduction_tp``; the matmul
    dispatch forwards it so the kernel registry can pick the per-shard
    shard_map route (``kernels.sharded``).
    """

    values: jnp.ndarray
    indices: jnp.ndarray  # uint8 in-group offsets
    n: int
    m: int
    group_axis: int
    shape: tuple  # dense shape at construction
    pad: int = 0  # alignment columns on the last axis of values/indices
    rshards: int = 1  # model-axis shards on the group (reduction) axis

    def tree_flatten(self):
        return (self.values, self.indices), (
            self.n, self.m, self.group_axis, self.shape, self.pad,
            self.rshards,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, indices = children
        return cls(values, indices, *aux)

    def dense(self) -> jnp.ndarray:
        d = nm_decompress(
            self.values, self.indices, self.n, self.m, self.group_axis
        )
        return d[..., : d.shape[-1] - self.pad] if self.pad else d

    @property
    def out_features(self) -> int:
        """True (unpadded) width of the last axis."""
        return self.values.shape[-1] - self.pad

    @property
    def nbytes(self) -> int:
        """Stored bytes — alignment padding included (it occupies HBM)."""
        return int(
            self.values.size * self.values.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
        )


def compress_params(
    params: Any, cfg: SparsityConfig, align: int | None = None
) -> Any:
    """Replace every maskable leaf with its N:M-compressed form.

    ``align``: pad the last (output) axis of each compressed buffer to this
    multiple at *compress time*, so the Pallas ``nm_spmm`` grid tiles the
    artifact without a per-call ``jnp.pad`` in the decode hot loop.  The
    true width rides on ``CompressedTensor.pad``.  Default: 128 (one MXU
    lane tile) when exporting on TPU, 1 (no padding) elsewhere — off-TPU
    the XLA path is alignment-indifferent and padding would only distort
    the compression ratio of tiny smoke models.  The default is keyed to
    the backend *compressing*, which matches the in-process flow
    (``launch/serve.py`` compresses on the machine that serves); when
    exporting a checkpoint on CPU for later TPU serving, pass
    ``align=128`` explicitly — an unaligned artifact still runs on TPU
    but re-enters ``nm_spmm_pallas``'s per-call pad fallback for
    non-gcd-friendly widths.  Only reduction-axis compressions
    (``group_axis == ndim-2``, the matmul layout) are padded.
    """
    if align is None:
        align = 128 if jax.default_backend() == "tpu" else 1

    def leaf(name, p):
        pat = cfg.pattern_for(name, tuple(p.shape))
        if pat is None or p.ndim < 2:
            return p
        v, i = nm_compress(p, pat.n, pat.m, pat.group_axis)
        pad = 0
        if align > 1 and pat.group_axis % p.ndim == p.ndim - 2:
            pad = -v.shape[-1] % align
            if pad:
                widths = ((0, 0),) * (v.ndim - 1) + ((0, pad),)
                v = jnp.pad(v, widths)
                i = jnp.pad(i, widths)
        return CompressedTensor(
            v, i, pat.n, pat.m, pat.group_axis, tuple(p.shape), pad
        )

    return tree_map_with_name(leaf, params)


def decompress_params(params: Any) -> Any:
    """Rehydrate a compressed tree to dense (debug / parity-test utility —
    the serving path never calls this; see ``models.layers.matmul``)."""
    return jax.tree_util.tree_map(
        lambda x: x.dense() if isinstance(x, CompressedTensor) else x,
        params,
        is_leaf=lambda x: isinstance(x, CompressedTensor),
    )


def compression_report(params: Any, compressed: Any) -> dict:
    """Bytes before/after (the decode-roofline input)."""

    def nbytes(x):
        return x.size * x.dtype.itemsize

    dense_b = sum(nbytes(x) for x in jax.tree_util.tree_leaves(params))
    comp_b = 0
    for leaf in jax.tree_util.tree_leaves(
        compressed, is_leaf=lambda x: isinstance(x, CompressedTensor)
    ):
        if isinstance(leaf, CompressedTensor):
            comp_b += leaf.nbytes
        else:
            comp_b += nbytes(leaf)
    return {
        "dense_bytes": int(dense_b),
        "compressed_bytes": int(comp_b),
        "ratio": comp_b / max(dense_b, 1),
    }
