"""Deployable N:M-compressed model export (the inference artifact).

``compress_params`` converts a trained parameter tree + SparsityConfig into
a tree where every maskable leaf is replaced by a :class:`CompressedTensor`
(values + packed indices). This is what a serving fleet would load: HBM
weight footprint drops to ~N/M (+1 byte/kept-element of index), and the
``kernels.nm_spmm`` Pallas kernel consumes the compressed form directly —
the TPU-native analogue of deploying onto Ampere Sparse Tensor Cores
(DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.masking import nm_compress, nm_decompress
from repro.core.sparsity_config import SparsityConfig
from repro.utils.tree import tree_map_with_name


class CompressedTensor(NamedTuple):
    values: jnp.ndarray
    indices: jnp.ndarray  # uint8 in-group offsets
    n: int
    m: int
    group_axis: int
    shape: tuple  # original dense shape

    def dense(self) -> jnp.ndarray:
        return nm_decompress(
            self.values, self.indices, self.n, self.m, self.group_axis
        )


def compress_params(params: Any, cfg: SparsityConfig) -> Any:
    """Replace every maskable leaf with its N:M-compressed form."""

    def leaf(name, p):
        pat = cfg.pattern_for(name, tuple(p.shape))
        if pat is None or p.ndim < 2:
            return p
        v, i = nm_compress(p, pat.n, pat.m, pat.group_axis)
        return CompressedTensor(v, i, pat.n, pat.m, pat.group_axis, tuple(p.shape))

    return tree_map_with_name(leaf, params)


def decompress_params(params: Any) -> Any:
    """Rehydrate a compressed tree to dense (reference serving path)."""
    return jax.tree_util.tree_map(
        lambda x: x.dense() if isinstance(x, CompressedTensor) else x,
        params,
        is_leaf=lambda x: isinstance(x, CompressedTensor),
    )


def compression_report(params: Any, compressed: Any) -> dict:
    """Bytes before/after (the decode-roofline input)."""

    def nbytes(x):
        return x.size * x.dtype.itemsize

    dense_b = sum(nbytes(x) for x in jax.tree_util.tree_leaves(params))
    comp_b = 0
    for leaf in jax.tree_util.tree_leaves(
        compressed, is_leaf=lambda x: isinstance(x, CompressedTensor)
    ):
        if isinstance(leaf, CompressedTensor):
            comp_b += nbytes(leaf.values) + nbytes(leaf.indices)
        else:
            comp_b += nbytes(leaf)
    return {
        "dense_bytes": int(dense_b),
        "compressed_bytes": int(comp_b),
        "ratio": comp_b / max(dense_b, 1),
    }
