from repro.sparse_infer.compress import (
    compress_params,
    decompress_params,
    CompressedTensor,
    compression_report,
)
