"""repro: a JAX training/inference framework with first-class N:M structured
sparsity, reproducing and extending *STEP: Learning N:M Structured Sparsity
Masks from Scratch with Precondition* (Lu et al., ICML 2023).

Public API highlights
---------------------
- ``repro.core``: N:M masking math, STE/SR-STE, the STEP two-phase optimizer
  and the AutoSwitch subroutine.
- ``repro.models``: the architecture zoo (dense GQA / MLA / MoE / SSM / hybrid).
- ``repro.configs``: assigned architecture configs (``get_config(name)``).
- ``repro.launch``: production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"
