"""Production training launcher.

    python -m repro.launch.train --arch gpt2-paper --recipe step \
        --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Builds the mesh from available devices (data x model), shards params/state
when >1 device, wires the synthetic corpus, STEP optimizer, AutoSwitch,
checkpointing with auto-resume, and logs the phase switch. On a real TPU
fleet the same entry point runs under `jax.distributed.initialize()` with
the production mesh from launch/mesh.py (the dry-run proves those configs
compile); on CPU it runs the smoke-scale configs end-to-end.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

import repro.core as core
from repro.checkpoint import Checkpointer
from repro.configs import get_config, list_archs
from repro.data import DataIterator, SyntheticLMDataset
from repro.models.model import TransformerLM, frontend_dim
from repro.train import Trainer, TrainerConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU); --no-smoke for the full config")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--recipe", default="step", choices=list(core.RECIPES))
    ap.add_argument("--nm", default="2:4")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--b2", type=float, default=0.98)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress-phase2", action="store_true",
                    help="1-bit EF gradient compression in the mask phase")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = TransformerLM(cfg)
    n, m = (int(x) for x in args.nm.split(":"))
    recipe = core.make_recipe(
        args.recipe,
        core.SparsityConfig(default=core.NMSparsity(n, m)),
        prune_at=int(0.3 * args.steps),
        dense_until=int(0.2 * args.steps),
    )
    scfg = core.StepConfig(
        learning_rate=args.lr,
        b2=args.b2,
        autoswitch=core.AutoSwitchConfig(
            eps=2e-5,
            window=min(100, int(round(1 / (1 - args.b2)))),
            t_min=int(0.1 * args.steps),
            t_max=int(0.5 * args.steps),
        ),
    )
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq, seed=42, n_states=16)

    def batch_fn(step, bs):
        b = ds.batch(step, bs)
        if cfg.frontend != "none":
            # stub frontend: derive frame/patch embeddings from the tokens
            key = jax.random.PRNGKey(step)
            b["embeds"] = jax.random.normal(
                key, (bs, args.seq, frontend_dim(cfg)), jnp.bfloat16
            )
            b.pop("tokens")
        return b

    def loss_fn(p, batch):
        return model.loss(p, batch, chunk=min(128, args.seq))

    data = DataIterator(batch_fn=batch_fn, batch_size=args.batch, prefetch=2)
    ck = Checkpointer(args.ckpt_dir, keep_last=3) if args.ckpt_dir else None

    def log(step, metrics):
        msg = {k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in metrics.items() if k in
               ("step", "loss", "ce", "grad_norm", "phase2", "z_bar", "t0", "step_time_s")}
        print(json.dumps(msg), flush=True)

    tr = Trainer(
        loss_fn, recipe, scfg, data,
        TrainerConfig(
            total_steps=args.steps,
            log_every=max(1, args.steps // 20),
            ckpt_every=args.ckpt_every if ck else 0,
            compress_phase2=args.compress_phase2,
        ),
        checkpointer=ck,
        log_fn=log,
    )
    params = model.init(jax.random.PRNGKey(args.seed))
    state, history = tr.run(params)
    data.close()

    sparse = recipe.export_sparse(state.params)
    eval_batch = batch_fn(10**6, args.batch)
    final_loss, _ = model.loss(sparse, eval_batch, chunk=min(128, args.seq))
    rep = core.sparsity_report(state.params, recipe.sparsity)
    summary = {
        "arch": cfg.name,
        "recipe": args.recipe,
        "final_sparse_eval_loss": float(final_loss),
        "phase2": bool(getattr(state.opt, "phase2", False)),
        "t0": int(getattr(state.opt, "t0", 0)),
        "maskable_fraction": round(rep["maskable_fraction"], 3),
        "removed_fraction": round(rep["removed_fraction_of_total"], 3),
    }
    print(json.dumps({"summary": summary}), flush=True)
    return summary


if __name__ == "__main__":
    main()
