"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS *before* any jax init, and
smoke tests must keep seeing 1 device.

Topology (TPU v5e target):
- single pod: (16, 16) over ("data", "model") = 256 chips.
- multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips;
  "pod" is an outer data-parallel axis (the model axes never cross the
  inter-pod DCI).
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None):
    """A ``(data, model)`` mesh over local devices (tests / examples / CPU).

    With only ``model`` given, ``data`` becomes ``n_devices // model`` —
    and a remainder now *warns* instead of silently dropping devices (the
    mesh uses the first ``data × model`` of them).  Callers that want an
    exact shape pass ``data`` explicitly; a shape needing more devices
    than exist raises.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs)
    if model < 1 or (data is not None and data < 1):
        raise ValueError(f"mesh axes must be >= 1, got data={data} model={model}")
    if data is None:
        if model > n:
            raise ValueError(f"model={model} exceeds the {n} local device(s)")
        if n % model:
            warnings.warn(
                f"make_local_mesh: {n} devices not divisible by model={model}; "
                f"using a ({n // model}, {model}) mesh over the first "
                f"{(n // model) * model} device(s)",
                stacklevel=2,
            )
        data = max(1, n // model)
    need = data * model
    if need > n:
        raise ValueError(
            f"mesh ({data}, {model}) needs {need} devices but only {n} exist"
        )
    if need == n:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.sharding.Mesh(
        np.array(devs[:need]).reshape(data, model), ("data", "model")
    )


# Hardware constants for the roofline model (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link (~ per sharded axis direction)
