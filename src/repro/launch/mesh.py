"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS *before* any jax init, and
smoke tests must keep seeing 1 device.

Topology (TPU v5e target):
- single pod: (16, 16) over ("data", "model") = 256 chips.
- multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips;
  "pod" is an outer data-parallel axis (the model axes never cross the
  inter-pod DCI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """A mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link (~ per sharded axis direction)
