import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, partitions, and compiles on the production mesh — and extract the
memory / cost / collective numbers the roofline analysis (§Roofline) reads.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); do not set it globally — tests and benches are
supposed to see 1 device.

Usage:
    python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out report.json]
"""

import argparse
import json
import time
import traceback
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as core
from repro.configs import ASSIGNED_ARCHS, get_config, SHAPES
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.synthetic import make_batch_specs
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    shardings_for,
    state_pspecs,
    tree_param_pspecs,
    _dp,
)
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train.loop import TrainState, make_train_step
from repro.core.step_optimizer import StepConfig, step_optimizer
from repro.utils import hlo_analysis as H
from repro.utils import hlo_cost as HC


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins; nothing is allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one cell."""
    if shape.kind == "train":
        return {"batch": make_batch_specs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        specs = make_batch_specs(cfg, shape.global_batch, shape.seq_len)
        specs.pop("labels")
        return {"batch": specs}
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        return {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            "cache": cache,
        }
    raise ValueError(shape.kind)


def abstract_train_state(cfg: ArchConfig, recipe: core.Recipe, step_cfg: StepConfig):
    opt = step_optimizer(step_cfg)

    def build():
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        return TrainState(
            params=params,
            opt=opt.init(params),
            recipe=recipe.init_state(params),
            comp=None,
            rng=jax.random.PRNGKey(0),
            data_state=jnp.zeros((2,), jnp.int32),
        )

    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# the three lowered programs
# ---------------------------------------------------------------------------


def _block_constraint(mesh, seq_axis: bool = True):
    """Sequence-parallel residual-stream constraint (bounds remat memory)."""
    dp = _dp(mesh)

    def fn(x):
        if x.ndim == 3:
            spec = P(dp, "model" if seq_axis else None, None)
        else:
            spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return fn


def make_recipe(cfg: ArchConfig, n: int = 2, m: int = 4) -> core.Recipe:
    return core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )


def lower_train(cfg: ArchConfig, shape: ShapeSpec, mesh, *, seq_shard=True,
                fsdp=True, nm=(2, 4)):
    recipe = make_recipe(cfg, *nm)
    step_cfg = StepConfig(learning_rate=1e-4)
    opt = step_optimizer(step_cfg)
    bc = _block_constraint(mesh, seq_axis=seq_shard)

    def loss(p, batch):
        return M.loss_fn(p, cfg, batch, remat=True, block_constraint=bc)

    step = make_train_step(loss, recipe, opt, grad_clip=1.0)
    state_abs = abstract_train_state(cfg, recipe, step_cfg)
    specs = input_specs(cfg, shape)
    state_sh = shardings_for(mesh, state_abs, state_pspecs(mesh, state_abs, fsdp=fsdp))
    batch_sh = shardings_for(mesh, specs["batch"], batch_pspecs(mesh, specs["batch"]))
    fn = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=0)
    return fn.lower(state_abs, specs["batch"])


def lower_prefill(cfg: ArchConfig, shape: ShapeSpec, mesh, *, seq_shard=True,
                  fsdp=True):
    bc = _block_constraint(mesh, seq_axis=seq_shard)

    def prefill_fn(params, batch):
        logits, _, caches = M.forward(
            params, cfg, batch, remat=False, want_cache=True, block_constraint=bc
        )
        return logits[:, -1, :], caches

    params_abs = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = input_specs(cfg, shape)
    p_sh = shardings_for(mesh, params_abs, tree_param_pspecs(params_abs, fsdp=fsdp))
    b_sh = shardings_for(mesh, specs["batch"], batch_pspecs(mesh, specs["batch"]))
    fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
    return fn.lower(params_abs, specs["batch"])


def lower_decode(cfg: ArchConfig, shape: ShapeSpec, mesh, *, fsdp=False, kv_shard="seq"):
    def serve_step(params, tokens, cache):
        return M.decode_step(params, cfg, tokens, cache)

    params_abs = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = input_specs(cfg, shape)
    # serving params: TP only (no FSDP — weights must be resident per step)
    from repro.distributed.sharding import sanitize_spec
    p_sh = shardings_for(mesh, params_abs, tree_param_pspecs(params_abs, fsdp=fsdp))
    t_sh = NamedSharding(
        mesh, sanitize_spec(P(_dp(mesh)), (shape.global_batch,), mesh)
    )
    c_sh = shardings_for(mesh, specs["cache"], cache_pspecs(mesh, specs["cache"], kv_shard=kv_shard))
    fn = jax.jit(serve_step, in_shardings=(p_sh, t_sh, c_sh), donate_argnums=2)
    return fn.lower(params_abs, specs["tokens"], specs["cache"])


LOWER = {"train": lower_train, "prefill": lower_prefill, "decode": lower_decode}


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(
    arch: str, shape_name: str, *, multi_pod: bool = False, **overrides
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_dev = 512 if multi_pod else 256
    report: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_dev,
    }
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        report["status"] = "skipped"
        report["reason"] = "full-attention arch: 500k dense KV decode is quadratic by construction (DESIGN.md §4)"
        return report
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        with mesh:
            lowered = LOWER[shape.kind](cfg, shape, mesh, **overrides)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = H.memory_analysis_dict(compiled)
        cost = H.cost_analysis_dict(compiled)
        text = compiled.as_text()
        coll = H.collective_bytes(text)
        walk = HC.analyze(text)  # trip-count-corrected (see utils/hlo_cost.py)
        report.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem,
            flops=walk["flops"],
            flops_xla_uncorrected=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            collectives={"total_bytes": walk["collective_total"],
                         "per_kind": walk["collective_bytes"],
                         "counts": coll.get("counts", {}),
                         "unknown_trip_count_whiles": walk["unknown_trip_count_whiles"]},
        )
    except Exception as e:  # report, don't crash the sweep
        report.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    return report


def all_cells(multi_pod: bool) -> list[tuple[str, str]]:
    cells = []
    for arch in ASSIGNED_ARCHS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            cells.append((arch, shape))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="incremental JSON report path")
    args = ap.parse_args()

    existing: dict[str, dict] = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)

    def key(arch, shape, mp):
        return f"{arch}|{shape}|{'mp' if mp else 'sp'}"

    todo: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:
            for arch, shape in all_cells(mp):
                todo.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    for arch, shape, mp in todo:
        k = key(arch, shape, mp)
        if k in existing and existing[k].get("status") in ("ok", "skipped"):
            print(f"[skip-cached] {k}")
            continue
        print(f"[run] {k} ...", flush=True)
        rep = run_cell(arch, shape, multi_pod=mp)
        line = {kk: rep.get(kk) for kk in ("status", "compile_s", "flops", "error")}
        print(f"  -> {line}", flush=True)
        if rep.get("status") == "ok":
            mem = rep["memory"]
            per_dev = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)
                       - mem.get("alias_size_in_bytes", 0))
            print(f"  memory/device ~ {per_dev/1e9:.2f} GB | collective GB "
                  f"{rep['collectives']['total_bytes']/1e9:.2f}", flush=True)
        existing[k] = rep
        if args.out:
            with open(args.out, "w") as f:
                json.dump(existing, f, indent=1)
    n_ok = sum(1 for r in existing.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in existing.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in existing.values() if r.get("status") == "error")
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}")


if __name__ == "__main__":
    main()
