"""Serving launcher: compressed-native continuous-batching decode.

    python -m repro.launch.serve --arch gpt2-paper --batch 4 --prompt-len 16 \
        --gen 32 [--ckpt-dir /tmp/run1] [--dense] [--temperature 0.8 --top-k 40] \
        [--paged --page-size 16 --num-pages 64] [--prefill-buckets 16,32,64] \
        [--steps-per-dispatch 4] [--prefill-chunk 16] [--no-donate]

Loads (or initializes) params, applies the final Π_T mask (Algorithm 1,
line 23-24), exports the N:M-compressed artifact, and hands the *compressed
tree itself* to ``repro.serving.DecodeEngine`` — prefill and every decode
step run directly on ``CompressedTensor`` leaves via the ``nm_spmm`` kernel
path (Pallas on TPU); the dense weights are never rehydrated in HBM.
``--dense`` serves the masked-dense tree instead, as an A/B baseline for
the same engine.  ``--paged`` switches the KV cache from the per-lane slab
to the block-granular paged pool (``--page-size``/``--num-pages``; an
undersized pool preempts-and-requeues instead of truncating), and
``--prefill-buckets`` overrides the static prompt-pad lengths used by
bucketed batched prefill.

Decode-loop knobs: ``--steps-per-dispatch K`` fuses K decode steps into one
on-device scan (the host syncs once per K tokens; greedy streams are
bit-identical across K), ``--prefill-chunk N`` absorbs long prompts in
N-token chunks interleaved with decode dispatches, and ``--no-donate``
disables cache-buffer donation (the copying A/B baseline).

Device-resident scheduler: ``--max-steps-per-dispatch K`` replaces the
fixed-K scan with a run-until-stop ``while_loop`` (the host is consulted
only when a lane freezes or the bound is hit), ``--staged-lanes Q``
pre-stages queued prompts on device so a frozen lane refills and starts
prefilling inside the same dispatch, and ``--async-stream``
double-buffers dispatches so token-block fetches overlap decode.
Streams stay bit-identical to the sync scheduler.

Paged-pool extensions: ``--prefix-cache`` indexes every prefilled prompt's
pages in a radix trie and maps cached prefixes into later requests' tables
(shared refcounted pages, copy-on-write on divergence; ``--shared-prefix N``
gives the synthetic requests a common head so hits actually occur), and
``--kv-int8`` stores KV pages as int8 with per-page-row scales — a ~4x
smaller pool at the same page count, dequantized inside the kernels.

``--mesh data,model`` serves **tensor-parallel**: every engine executable
is jitted with explicit NamedShardings (weights TP via the compressed
pspec seam, KV caches sequence/pages-sharded per ``--kv-shard``), and the
summary grows per-shard HBM bytes and the decode executable's collective
counts.  On CPU, emulate a mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 ... --mesh 2,4``.

Self-speculative decoding: ``--spec-gamma N`` (or ``auto``) drafts N
tokens per lane with the *serving tree* (the compressed N:M artifact, or
the masked-dense tree under ``--dense``) and verifies them in one chunked
pass through the masked-dense weights — both trees fall out of the same
STEP run, no separately trained drafter.  Output streams are exactly the
dense verifier's (longest-prefix accept under greedy, rejection sampling
otherwise); the summary gains ``acceptance_rate``, ``spec_gamma``, and
draft/verify token counts next to ``kernel_route``.  ``auto`` picks γ
from the drafter/verifier byte ratio via the engine's roofline model.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

import repro.core as core
from repro.checkpoint import Checkpointer
from repro.configs import get_config, list_archs
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params, compression_report


def build_serving_state(args) -> tuple:
    """(model, serving_tree, compression_report, sparse_tree) from CLI
    args.  ``sparse_tree`` (the masked-dense Π_T ⊙ w_T weights) doubles
    as the speculative verifier — the two fidelities of one STEP run."""
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.frontend != "none":
        raise SystemExit("serve demo targets token-input archs")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        # train.py checkpoints store the whole TrainState; NamedTuple fields
        # flatten by field name, so a {"params": ...} skeleton reads just the
        # parameter subtree out of the full-state npz.
        restored = Checkpointer(args.ckpt_dir).restore_latest({"params": params})
        if restored is not None:
            tree, _, step = restored
            params = tree["params"]
            print(f"# restored params from step {step}")

    n, m = (int(x) for x in args.nm.split(":"))
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)  # Π_T ⊙ w_T
    comp = compress_params(sparse, recipe.sparsity)
    rep = compression_report(sparse, comp)
    serving_tree = sparse if args.dense else comp
    return model, serving_tree, rep, sparse


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--nm", default="2:4")
    ap.add_argument("--batch", type=int, default=4, help="decode lanes")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests (default: one per lane)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--dense", action="store_true",
                    help="serve the masked-dense tree (A/B baseline)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache pool instead of the per-lane slab")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="total pages in the pool (default: slab-equivalent "
                         "batch*ceil(max_len/page_size))")
    ap.add_argument("--prefill-buckets", default=None,
                    help="comma-separated static prompt-pad lengths for "
                         "bucketed batched prefill (default: powers of two)")
    ap.add_argument("--steps-per-dispatch", type=int, default=1,
                    help="decode steps fused into one on-device scan (the "
                         "host syncs once per K tokens)")
    ap.add_argument("--max-steps-per-dispatch", type=int, default=None,
                    help="device-resident scheduler: run-until-stop decode "
                         "bounded by this many steps per dispatch (replaces "
                         "the fixed-K scan; streams stay bit-identical)")
    ap.add_argument("--staged-lanes", type=int, default=0,
                    help="queued prompts pre-staged on device per cycle so "
                         "frozen lanes refill inside the dispatch (needs "
                         "--max-steps-per-dispatch)")
    ap.add_argument("--async-stream", action="store_true",
                    help="double-buffer decode dispatches: fetch dispatch "
                         "N's tokens while N+1 runs (needs "
                         "--max-steps-per-dispatch)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="absorb prompts longer than this in fixed-size "
                         "chunks interleaved with decode dispatches "
                         "(attention-family archs only)")
    ap.add_argument("--no-donate", dest="donate", action="store_false",
                    default=True,
                    help="disable cache-buffer donation into the jitted "
                         "decode/prefill (the copying A/B baseline)")
    ap.add_argument("--mesh", default=None,
                    help="serve tensor-parallel on a 'data,model' mesh over "
                         "local devices (e.g. --mesh 2,4 under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8); "
                         "weights TP-shard, KV caches sequence/pages-shard, "
                         "and the summary gains per-shard HBM bytes + decode "
                         "collective counts")
    ap.add_argument("--kv-shard", default="seq", choices=("seq", "feature"),
                    help="model-axis dim of the KV caches under --mesh")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests via "
                         "the radix index (paged, attention-family archs); "
                         "hits skip prefilling the cached tokens")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV pages with per-page-row scales (~4x "
                         "smaller pool at equal page count; paged only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request the same leading N prompt "
                         "tokens (exercises --prefix-cache; the tail stays "
                         "per-request random)")
    ap.add_argument("--spec-gamma", default=None,
                    help="self-speculative decoding: draft this many tokens "
                         "per lane with the serving tree, verify in one "
                         "chunked pass through the masked-dense weights "
                         "('auto' picks gamma from the byte-ratio roofline; "
                         "attention-family archs, sync scheduler only)")
    args = ap.parse_args(argv)
    spec_gamma = None
    if args.spec_gamma is not None:
        spec_gamma = (
            "auto" if args.spec_gamma == "auto" else int(args.spec_gamma)
        )
    if (args.prefix_cache or args.kv_int8) and not args.paged:
        raise SystemExit("--prefix-cache/--kv-int8 require --paged")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_local_mesh

        d, m = (int(v) for v in args.mesh.split(","))
        mesh = make_local_mesh(m, data=d)

    model, serving_tree, rep, sparse = build_serving_state(args)
    cfg = model.cfg
    print(json.dumps({"compression": rep}))

    max_len = args.prompt_len + args.gen + 1
    num_pages = args.num_pages
    if args.paged and num_pages is None:
        num_pages = args.batch * (-(-max_len // args.page_size))
    buckets = (
        [int(b) for b in args.prefill_buckets.split(",")]
        if args.prefill_buckets
        else None
    )
    engine = DecodeEngine(
        model,
        serving_tree,
        max_batch=args.batch,
        max_len=max_len,
        seed=0,
        num_pages=num_pages if args.paged else None,
        page_size=args.page_size,
        steps_per_dispatch=args.steps_per_dispatch,
        max_steps_per_dispatch=args.max_steps_per_dispatch,
        staged_lanes=args.staged_lanes,
        async_stream=args.async_stream,
        donate=args.donate,
        prefill_chunk=args.prefill_chunk,
        prefill_buckets=buckets,
        mesh=mesh,
        kv_shard=args.kv_shard,
        prefix_cache=args.prefix_cache,
        kv_quant=args.kv_int8,
        spec_gamma=spec_gamma,
        # masked-dense verifier: with --dense the drafter IS the verifier
        # (acceptance is then 1.0 by construction — a plumbing check)
        verify_params=sparse if spec_gamma is not None else None,
    )
    n_requests = args.batch if args.requests is None else args.requests
    sampling = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, max_new_tokens=args.gen
    )
    shared = []
    if args.shared_prefix:
        n_shared = min(args.shared_prefix, args.prompt_len - 1)
        shared = [
            int(t) for t in jax.random.randint(
                jax.random.PRNGKey(999), (n_shared,), 0, cfg.vocab
            )
        ]
    for r in range(n_requests):
        prompt = jax.random.randint(
            jax.random.PRNGKey(1000 + r),
            (args.prompt_len - len(shared),), 0, cfg.vocab,
        )
        engine.submit(shared + [int(t) for t in prompt], sampling)
    results = engine.run()

    st = engine.stats()
    summary = {
        "arch": cfg.name,
        "compressed": not args.dense,
        "layout": st["layout"],
        "n_requests": len(results),
        "generated_tokens": st["tokens_generated"],
        "tokens_per_s": st["tokens_per_s"],
        "ms_per_decode_step": st["ms_per_decode_step"],
        "ms_per_decode_step_host": st["ms_per_decode_step_host"],
        "host_overhead_frac": st["host_overhead_frac"],
        "decode_steps": st["decode_steps"],
        "dispatches": st["dispatches"],
        "steps_per_dispatch": st["steps_per_dispatch"],
        "scheduler": st["scheduler"],
        "host_syncs": st["host_syncs"],
        "refills": st["refills"],
        "itl_ms_p50": st["itl_ms_p50"],
        "itl_ms_p99": st["itl_ms_p99"],
        "prefill_batches": st["prefill_batches"],
        "prefill_chunks": st["prefill_chunks"],
        "max_concurrency": st["max_concurrency"],
        "preemptions": st["preemptions"],
        "kv_cache_bytes": st["kv_cache_bytes"],
        "hbm_weight_ratio": round(rep["ratio"], 3),
        "mesh": engine.mesh_desc(),
        # which paged-attention implementation decode resolved at trace
        # time ("slab" when no paged kernel is in play) — serve_bench's
        # sharded sweep compares xla vs shard_map streams on this field
        "kernel_route": engine.kernel_route(),
    }
    if spec_gamma is not None:
        # speculative health next to the route: how long the drafts ran,
        # how many survived the dense verifier, and the amortized weight
        # stream each committed token paid for
        for key in (
            "spec_gamma", "spec_rounds", "draft_tokens", "verify_tokens",
            "accepted_draft_tokens", "acceptance_rate",
            "accepted_per_verify", "bytes_per_accepted_token",
        ):
            summary[key] = st[key]
    if args.paged:
        # pool/page-sharing health next to the route: sync costs, window
        # reclamation, and the prefix-cache / copy-on-write counters
        for key in (
            "evicted_pages", "table_full_uploads", "table_row_syncs",
            "table_syncs", "kv_quant", "shared_pages", "cow_copies",
        ):
            summary[key] = st[key]
        for key in (
            "prefix_hits", "prefix_hit_tokens", "prefix_hit_rate",
            "prefix_indexed_pages", "prefix_evictions",
        ):
            if key in st:
                summary[key] = st[key]
    if args.temperature == 0.0:
        # greedy streams are deterministic: recorded so route/mesh A/B
        # runs can assert token-level parity from the summaries alone
        summary["greedy_streams"] = [
            [int(t) for t in results[u].tokens] for u in sorted(results)
        ]
    if mesh is not None:
        sh = engine.sharding_report(include_hlo=True)
        summary["weight_bytes_per_shard"] = sh["weight_bytes_per_shard"]
        summary["cache_bytes_per_shard"] = sh["cache_bytes_per_shard"]
        summary["decode_collective_bytes"] = sh["decode_collective_bytes"]
        summary["decode_collective_total"] = sh["decode_collective_total"]
        # matmul weights only: per-feature vectors replicate by design and
        # would make this column constant nonzero noise
        summary["replicated_weight_leaves"] = sh["replicated_matmul_leaves"]
        # per-shard decode roofline: every shard streams its weight slice
        # each step, and the pages/sequence axis splits the live-KV read
        # over the model axis
        sizes = dict(zip(summary["mesh"]["axes"], summary["mesh"]["shape"]))
        model_shards = int(sizes.get("model", 1))
        summary["model_shards"] = model_shards
        summary["weight_bytes_per_step_per_shard"] = sh["weight_bytes_per_shard"]
        summary["kv_bytes_per_step_per_shard"] = (
            st["kv_bytes_per_step"] / model_shards
        )
        summary["bytes_read_per_step_per_shard"] = (
            sh["weight_bytes_per_shard"] + st["kv_bytes_per_step"] / model_shards
        )
    print(json.dumps({"summary": summary}))
    return summary


if __name__ == "__main__":
    main()
