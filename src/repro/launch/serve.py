"""Serving launcher: batched greedy decode from an N:M-compressed model.

    python -m repro.launch.serve --arch gpt2-paper --batch 4 --prompt-len 16 \
        --gen 32 [--ckpt-dir /tmp/run1]

Loads (or initializes) params, applies the final Π_T mask (Algorithm 1,
line 23-24), exports the N:M-compressed artifact, reports the HBM footprint
win, and runs a batched KV-cache decode loop — the serving path whose
weight reads the nm_spmm Pallas kernel compresses on TPU.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

import repro.core as core
from repro.checkpoint import Checkpointer
from repro.configs import get_config, list_archs
from repro.models.model import TransformerLM
from repro.sparse_infer import compress_params, compression_report, decompress_params


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--nm", default="2:4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.frontend != "none":
        raise SystemExit("serve demo targets token-input archs")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        # train.py checkpoints store the whole TrainState; NamedTuple fields
        # flatten by field name, so a {"params": ...} skeleton reads just the
        # parameter subtree out of the full-state npz.
        ck = Checkpointer(args.ckpt_dir)
        step = ck.latest_step()
        if step is not None:
            from repro.checkpoint.checkpointer import load_pytree

            tree, _ = load_pytree(ck._step_dir(step), {"params": params})
            params = tree["params"]
            print(f"# restored params from step {step}")

    n, m = (int(x) for x in args.nm.split(":"))
    recipe = core.make_recipe("step", core.SparsityConfig(default=core.NMSparsity(n, m)))
    sparse = recipe.export_sparse(params)  # Π_T ⊙ w_T
    comp = compress_params(sparse, recipe.sparsity)
    rep = compression_report(sparse, comp)
    print(json.dumps({"compression": rep}))
    serving_params = decompress_params(comp)  # reference path (nm_spmm on TPU)

    toks = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    max_len = args.prompt_len + args.gen + 1
    logits, cache = model.prefill(serving_params, {"tokens": toks}, max_len=max_len)
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen):
        logits, cache = step(serving_params, tok, cache)
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    seqs = jnp.stack(out, axis=1)
    summary = {
        "arch": cfg.name,
        "generated_shape": list(seqs.shape),
        "tokens_per_s": args.gen * args.batch / dt,
        "ms_per_decode_step": dt / args.gen * 1e3,
        "hbm_weight_ratio": round(rep["ratio"], 3),
    }
    print(json.dumps({"summary": summary}))
    return summary


if __name__ == "__main__":
    main()
