"""Backend-aware kernel dispatch: one registry for every kernel call.

Why this exists: the seed wired ``nm_spmm_pallas(..., interpret=True)``
defaults straight into the serving matmul and left the CPU production path
on a ``put_along_axis`` scatter-decompress — running either a Pallas kernel
under the Python interpreter or an XLA scatter inside the decode hot loop.
That is how compressed decode measured ~8x *slower* than dense at batch 1
(``BENCH_serve.json``, PR 2).  Kernel routing belongs in one place, decided
by backend + shape, never hardcoded at a call site.

Modes
-----
- ``"pallas"``    — the compiled Pallas-TPU kernel (backend == "tpu").
- ``"interpret"`` — the same kernel body under the Pallas interpreter.
  Correctness-only: tests and debugging.  Never a production route.
- ``"xla"``       — a vectorized pure-XLA implementation.  The production
  path on CPU/GPU and the parity oracle everywhere.
- ``"shard_map"`` — a ``jax.experimental.shard_map`` wrapper that runs the
  kernel *per shard* over mesh-partitioned operands and combines partial
  results with tiny psums (``kernels.sharded``).  Selected automatically
  for ``shards > 1`` calls (see below); forcing it on an unsharded call
  falls through to the backend default.

Resolution order, first hit wins:

1. an explicit ``mode=...`` argument at the call site,
2. the innermost active :func:`force_mode` context (tests),
3. the ``REPRO_KERNEL_MODE`` environment variable (CI / smoke runs),
4. a per-kernel *shape guard* — shapes the Pallas grid cannot tile
   efficiently (e.g. a reduction dim whose only valid block size is
   degenerate) fall back to ``"xla"`` even on TPU,
5. the backend default: ``tpu -> "pallas"``, anything else ``-> "xla"``.

One override sits above all of these: ``shards > 1`` in the shape info
(operands partitioned across a mesh, e.g. a mesh-native engine's paged
pool — see ``PagedLayout.shards``) re-routes any non-``"xla"`` pick,
because a raw Pallas body is opaque to GSPMD and cannot be partitioned.
When a ``"shard_map"`` wrapper is registered for the kernel, a mesh is
active (:func:`mesh_context` — the mesh-native engine installs it around
every executable call), and the per-kernel *shard guard* accepts the
shape (divisibility: pages per shard, whole N:M groups per shard), the
call routes to the wrapper — the kernel runs per shard on shard-local
operands and the partial results combine with the same tiny psums the
XLA gathered path uses.  Otherwise ``"xla"`` remains the correctness
backstop: GSPMD partitions the gathered implementation.  The mode that
would have been picked without the override (forced ``"interpret"``, the
TPU ``"pallas"`` default, ...) becomes the *inner* per-shard route,
resolved by the wrapper through this same registry.

Resolution happens at trace time: a jitted caller bakes the route into its
executable, so flipping the env var after an engine compiled its decode
step does not re-route that engine (build a new one, as ``scripts/smoke.sh``
does for the forced-XLA serve invocation).

Registered kernels: ``nm_spmm`` (compressed N:M matmul), ``paged_attn``
(paged decode attention), ``nm_mask`` (fused mask-compute-and-apply; the
training-loop hot spot).  The legacy ``prefer_pallas``/``interpret`` knobs
that ``kernels.ops`` carried from the seed are retired — call sites pass
``mode=`` or rely on the resolution order above.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable, Optional

import jax

ENV_VAR = "REPRO_KERNEL_MODE"
MODES = ("pallas", "interpret", "xla", "shard_map")

_REGISTRY: dict[str, dict[str, Callable]] = {}
_GUARDS: dict[str, Callable[..., bool]] = {}
_SHARD_GUARDS: dict[str, Callable[..., bool]] = {}
_FORCED: list[str] = []
_MESHES: list = []  # trace-time mesh stack for the shard_map route


def register(kernel: str, mode: str, fn: Callable) -> None:
    """Register ``fn`` as the ``mode`` implementation of ``kernel``."""
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    _REGISTRY.setdefault(kernel, {})[mode] = fn


def register_guard(kernel: str, guard: Callable[..., bool]) -> None:
    """``guard(**shape_info) -> bool``: may the Pallas route take this shape?"""
    _GUARDS[kernel] = guard


def register_shard_guard(kernel: str, guard: Callable[..., bool]) -> None:
    """``guard(**shape_info) -> bool``: may the shard_map route take this
    sharded call?  (Divisibility checks: the wrapper's in_specs split
    operand dims exactly — pages per shard, whole N:M groups per shard.)"""
    _SHARD_GUARDS[kernel] = guard


@contextlib.contextmanager
def mesh_context(mesh):
    """Make ``mesh`` available to trace-time resolution: ``shards > 1``
    calls inside the context may route to a registered shard_map wrapper
    (which needs the concrete mesh to build its ``shard_map``).  The
    mesh-native serving engine installs this around every executable call;
    without it, sharded calls take the XLA backstop exactly as before."""
    _MESHES.append(mesh)
    try:
        yield
    finally:
        _MESHES.pop()


def active_mesh():
    """The innermost :func:`mesh_context` mesh, or None."""
    return _MESHES[-1] if _MESHES else None


def registered() -> dict[str, tuple[str, ...]]:
    """kernel name -> modes with an implementation (introspection / tests)."""
    _ensure_registered()
    return {k: tuple(sorted(v)) for k, v in _REGISTRY.items()}


@contextlib.contextmanager
def force_mode(mode: str):
    """Force every dispatch inside the context to ``mode`` (tests)."""
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    _FORCED.append(mode)
    try:
        yield
    finally:
        _FORCED.pop()


def _env_mode() -> Optional[str]:
    mode = os.environ.get(ENV_VAR, "").strip().lower()
    if not mode:
        return None
    if mode not in MODES:
        raise ValueError(f"{ENV_VAR}={mode!r}; expected one of {MODES}")
    return mode


def _ensure_registered(kernel: str = "") -> None:
    """Implementations self-register at import; pull their modules in."""
    if (
        "nm_spmm" not in _REGISTRY
        or "paged_attn" not in _REGISTRY
        or "nm_mask" not in _REGISTRY
        or "shard_map" not in _REGISTRY.get("paged_attn", {})
    ):
        import repro.kernels.nm_mask  # noqa: F401
        import repro.kernels.nm_spmm  # noqa: F401
        import repro.kernels.paged_attn  # noqa: F401
        import repro.kernels.sharded  # noqa: F401


def _default_mode(kernel: str, **shape_info) -> str:
    picked = "pallas" if jax.default_backend() == "tpu" else "xla"
    guard = _GUARDS.get(kernel)
    if picked == "pallas" and guard is not None and not guard(**shape_info):
        picked = "xla"  # shape the Pallas grid can't tile: use XLA even on TPU
    return picked


def _shard_route_ok(kernel: str, impls: dict, shape_info: dict) -> bool:
    """May this ``shards > 1`` call take the registered shard_map wrapper?
    Needs the wrapper, an active :func:`mesh_context` whose model axis
    matches the shard count, and the kernel's shard guard's blessing."""
    if "shard_map" not in impls:
        return False
    mesh = active_mesh()
    if mesh is None:
        return False
    from repro.distributed.sharding import MODEL_AXIS

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if int(sizes.get(MODEL_AXIS, 1)) != int(shape_info.get("shards", 1)):
        return False
    guard = _SHARD_GUARDS.get(kernel)
    return guard is None or bool(guard(**shape_info))


def resolve(kernel: str, mode: Optional[str] = None, **shape_info) -> tuple[str, Callable]:
    """Pick ``(mode, impl)`` for one kernel call.  See module docstring."""
    _ensure_registered(kernel)
    impls = _REGISTRY[kernel]
    picked = mode or (_FORCED[-1] if _FORCED else None) or _env_mode()
    if picked is None:
        picked = _default_mode(kernel, **shape_info)
    if shape_info.get("shards", 1) > 1 and picked != "xla":
        # mesh-partitioned operands: a raw Pallas body is opaque to GSPMD.
        # Route to the shard_map wrapper (per-shard kernel on shard-local
        # operands + psum combine) when one is registered and eligible;
        # the GSPMD-partitionable XLA implementation is the correctness
        # backstop.  Forced/env modes are overridden here too — they
        # become the *inner* per-shard route inside the wrapper instead.
        if _shard_route_ok(kernel, impls, shape_info):
            picked = "shard_map"
        elif "xla" in impls:
            picked = "xla"
    elif picked == "shard_map":
        # forced shard_map on an unsharded call (env-forced sweeps hit
        # every kernel): nothing to wrap — fall through to the default
        picked = _default_mode(kernel, **shape_info)
    if picked not in impls:
        raise NotImplementedError(f"kernel {kernel!r} has no {picked!r} impl")
    return picked, impls[picked]


def uses_kernel(kernel: str, mode: Optional[str] = None, **shape_info) -> bool:
    """True when dispatch would run the fused Pallas kernel body (compiled
    or interpreted) rather than the XLA reference.  Call sites that must
    *restructure* around the kernel (e.g. paged decode skipping the
    contiguous gather) branch on this at trace time."""
    return resolve(kernel, mode, **shape_info)[0] != "xla"


# ---------------------------------------------------------------------------
# public kernel entry points
# ---------------------------------------------------------------------------


def nm_spmm(
    x, values, indices, n: int, m: int, *, o_true: Optional[int] = None,
    shards: int = 1, mode: Optional[str] = None,
):
    """Compressed N:M matmul ``y = x @ decompress(values, indices)``.

    ``o_true`` slices off compress-time MXU padding on the output dim
    (``sparse_infer.compress_params`` stores lane-aligned buffers; the true
    width rides on ``CompressedTensor.pad``).

    ``shards``: how many model-axis shards partition the *group* (reduction)
    axis of ``values``/``indices`` (``CompressedTensor.rshards``, stamped by
    ``distributed.compressed_pspecs.annotate_reduction_tp``).  With
    ``shards > 1`` and an active :func:`mesh_context` the call routes to
    the per-shard shard_map wrapper (``kernels.sharded.nm_spmm_shard_map``:
    whole N:M groups per shard by construction, partial outputs
    psum-reduced); otherwise GSPMD partitions the XLA path.
    """
    picked, fn = resolve(
        "nm_spmm", mode, b=x.shape[0], k=x.shape[-1], o=values.shape[-1],
        n=n, m=m, shards=shards,
    )
    if picked == "shard_map":
        return fn(x, values, indices, n, m, o_true=o_true, mesh=active_mesh())
    return fn(x, values, indices, n, m, o_true=o_true)


def nm_mask(w, n: int, m: int, *, mode: Optional[str] = None):
    """Fused N:M mask computation + application: ``(Π, Π⊙w)``.

    The Pallas kernel tiles 2-D weights with whole N:M groups running down
    the rows (axis 0 — the matmul reduction axis); other ranks/shapes are
    rare and small in the zoo and take the XLA reference on every mode, so
    a forced ``pallas``/``interpret`` run never hits the kernel's shape
    asserts mid-sweep.
    """
    if w.ndim != 2 or w.shape[0] % m:
        mode = "xla"
    _, fn = resolve("nm_mask", mode, ndim=w.ndim, rows=w.shape[0], m=m)
    return fn(w, n, m)


def paged_attn(
    q, k_pages, v_pages, tables, lengths, *, scale: float,
    window: int = 0, win_slots: int = 0, q2=None, k2_pages=None,
    k_scale=None, v_scale=None, k2_scale=None,
    v_is_k: bool = False, shards: int = 1, mode: Optional[str] = None,
):
    """Paged decode attention over a ``(P, ps, Hkv, D)`` pool + page table.

    See ``kernels.paged_attn`` for the argument contract (GQA and
    MLA-latent layouts, sentinel slots, windowed modular tables).
    ``k_scale``/``v_scale``/``k2_scale`` are the int8 pool's per-(page,
    slot) dequantization planes (``PagedLayout.quant``); every route —
    Pallas, interpret, the XLA gathered twin, and the shard_map stats
    variant — applies them per page under the same flash math.

    ``shards``: how many mesh shards partition the pool's pages axis
    (``PagedLayout.shards``).  With ``shards > 1`` and an active
    :func:`mesh_context`, the call routes to the shard_map wrapper
    (``kernels.sharded.paged_attn_shard_map``): each shard remaps the
    replicated table to shard-local page ids, runs the kernel over its
    slice of the pool emitting unnormalized flash ``(acc, m, l)`` stats,
    and the softmax combines via tiny psums — the same stats/psum shape
    GSPMD derives for the XLA gathered path, which remains the backstop
    when no mesh is active or the pool doesn't split evenly.
    """
    picked, fn = resolve(
        "paged_attn", mode, b=q.shape[0], n_slots=tables.shape[1],
        page_size=k_pages.shape[1], num_pages=k_pages.shape[0],
        shards=shards,
    )
    kw = dict(
        scale=scale, window=window, win_slots=win_slots, q2=q2,
        k2_pages=k2_pages, v_is_k=v_is_k,
        k_scale=k_scale, v_scale=v_scale, k2_scale=k2_scale,
    )
    if picked == "shard_map":
        kw["mesh"] = active_mesh()
    return fn(q, k_pages, v_pages, tables, lengths, **kw)
