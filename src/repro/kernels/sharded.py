"""Per-shard kernel wrappers: the ``"shard_map"`` dispatch route.

A raw Pallas body is opaque to GSPMD: before this module, any ``shards > 1``
call (mesh-native engine, pool or reduction axis model-sharded) was forced
onto the XLA implementation and the compiled fast path was exactly the one
lost at scale.  These wrappers run the *same* kernels per shard under
``jax.experimental.shard_map`` and combine partial results with the tiny
psums GSPMD already derives for the gathered/sharded XLA paths:

- ``paged_attn_shard_map`` — the KV pool's *pages* axis is model-sharded
  (``serving_cache_pspecs``: ``P(MODEL_AXIS, ...)``), page tables and
  queries are replicated.  Each shard rewrites the replicated table to
  shard-local page ids (:func:`shard_local_tables`: pages resident on this
  shard keep ``phys - shard·per`` and everything else becomes the *local*
  sentinel ``per``, which is precisely the inner kernel's unmapped-slot
  convention ``sentinel = pool_size``), runs the stats-emitting kernel over
  its pool slice, and the flash ``(acc, m, l)`` triples renormalize across
  shards in :func:`combine_stats` — one pmax and two psums over
  ``(B, Hkv, G[, Dv])``-sized tensors, bytes-trivial next to the pool.

- ``nm_spmm_shard_map`` — compressed leaves are reduction-TP'd
  (``compressed_pspecs``: the group axis splits over the model axis, and
  whole N:M groups never straddle shards because eligibility requires
  ``dense_in % (m · axis_size) == 0``).  ``x`` splits on K, each shard
  multiplies its group rows, and partial outputs psum-reduce in f32.

The *inner* per-shard route resolves through the same dispatch registry at
trace time, so ``force_mode("interpret")`` / ``REPRO_KERNEL_MODE`` sweeps
exercise the kernel body under the wrapper for free, and on TPU the inner
route is the compiled Pallas kernel.

Windowed/modular table math is safe under remapping: which slot holds
which *logical* page depends only on the slot index and the lane length,
never on the physical page id the slot stores — so rewriting physical ids
to shard-local ones (or the sentinel) preserves the live-window masks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MODEL_AXIS
from repro.kernels import dispatch


def shard_local_tables(tables, shard, pages_per_shard):
    """Rewrite a replicated page table to one shard's local view.

    ``tables`` holds global physical page ids (sentinel = global pool
    size).  Returns ``(local_tables, resident)``: entries whose page lives
    on ``shard`` (``shard·per <= phys < (shard+1)·per``) become
    ``phys - shard·per``; every other entry — other shards' pages *and*
    the global sentinel — becomes the local sentinel ``pages_per_shard``,
    exactly the unmapped-slot convention of the inner kernel (whose
    sentinel is its own pool size).  ``resident`` is the boolean mask of
    entries that survived.  A lane with zero resident pages on a shard
    yields an all-sentinel row; the inner kernel emits dead-lane stats
    (``m = -1e30, l = 0, acc = 0``) which contribute nothing to the
    cross-shard combine.
    """
    lo = shard * pages_per_shard
    local = tables - lo
    resident = (local >= 0) & (local < pages_per_shard)
    return jnp.where(resident, local, pages_per_shard).astype(tables.dtype), resident


def combine_stats(acc, m, l, axis_name):
    """Renormalize per-shard flash stats into the global softmax output.

    Standard flash-attention combine over a named mesh axis: global max by
    pmax, correction factors ``exp(m - m_g)`` rescale each shard's
    denominator and accumulator, then two psums and one divide.  Dead
    shards (``m = -1e30, l = 0``) contribute exact zeros; a lane dead on
    *every* shard keeps ``l_g = 0`` and flushes zeros through the clamp,
    matching the single-shard kernel's dead-lane behavior.
    """
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    acc_g = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_g / jnp.maximum(l_g, 1e-30)[..., None]


def paged_attn_shard_map(
    q: jnp.ndarray,  # (B, Hkv, G, D), replicated
    k_pages: jnp.ndarray,  # (P, ps, Hkv, D), pages axis model-sharded
    v_pages: Optional[jnp.ndarray],  # (P, ps, Hkv, Dv) or None when v_is_k
    tables: jnp.ndarray,  # (B, n_slots) int32, replicated
    lengths: jnp.ndarray,  # (B,) int32, replicated
    *,
    scale: float,
    window: int = 0,
    win_slots: int = 0,
    q2: Optional[jnp.ndarray] = None,
    k2_pages: Optional[jnp.ndarray] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (P, ps), pages axis sharded
    v_scale: Optional[jnp.ndarray] = None,
    k2_scale: Optional[jnp.ndarray] = None,
    v_is_k: bool = False,
    mesh=None,
    inner_mode: Optional[str] = None,
) -> jnp.ndarray:
    """Paged decode attention with the pool's pages axis model-sharded.

    The dispatch shard guard already checked ``num_pages % shards == 0``.
    Queries/tables/lengths stay replicated (batch is small and may not
    divide the data axis; GSPMD reshards the tiny activations around the
    wrapper for free) — the point is that the *pool* never moves.  int8
    scale planes shard with their pages axis and dequantize inside each
    shard's inner kernel.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shards = int(sizes.get(MODEL_AXIS, 1))
    per = k_pages.shape[0] // shards
    has_k2 = q2 is not None
    has_scale = k_scale is not None

    operands = [q, tables, lengths, k_pages]
    specs = [P(), P(), P(), P(MODEL_AXIS)]
    if has_scale:
        operands.append(k_scale)
        specs.append(P(MODEL_AXIS))
    if has_k2:
        operands += [q2, k2_pages]
        specs += [P(), P(MODEL_AXIS)]
        if has_scale:
            operands.append(k2_scale)
            specs.append(P(MODEL_AXIS))
    if not v_is_k:
        operands.append(v_pages)
        specs.append(P(MODEL_AXIS))
        if has_scale:
            operands.append(v_scale)
            specs.append(P(MODEL_AXIS))

    def body(q_, tables_, lengths_, k_local, *rest):
        it = iter(rest)
        ks_ = next(it) if has_scale else None
        q2_ = next(it) if has_k2 else None
        k2_ = next(it) if has_k2 else None
        k2s_ = next(it) if (has_k2 and has_scale) else None
        v_ = None if v_is_k else next(it)
        vs_ = None if v_is_k else (next(it) if has_scale else None)
        shard = jax.lax.axis_index(MODEL_AXIS)
        local, _ = shard_local_tables(tables_, shard, per)
        _, fn = dispatch.resolve(
            "paged_attn_stats", inner_mode, b=q_.shape[0],
            n_slots=tables_.shape[1], page_size=k_local.shape[1],
            num_pages=per, shards=1,
        )
        acc, m, l = fn(
            q_, k_local, v_, local, lengths_, scale=scale, window=window,
            win_slots=win_slots, q2=q2_, k2_pages=k2_, v_is_k=v_is_k,
            k_scale=ks_, v_scale=vs_, k2_scale=k2s_,
        )
        return combine_stats(acc, m, l, MODEL_AXIS).astype(q_.dtype)

    return shard_map(
        body, mesh, in_specs=tuple(specs), out_specs=P(), check_rep=False
    )(*operands)


def nm_spmm_shard_map(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K·n/m, O)
    indices: jnp.ndarray,  # (K·n/m, O) uint8
    n: int,
    m: int,
    o_true: Optional[int] = None,
    *,
    mesh=None,
    inner_mode: Optional[str] = None,
) -> jnp.ndarray:
    """Compressed N:M matmul with the group (reduction) axis model-sharded.

    The dispatch shard guard already checked ``k % (m · shards) == 0``, so
    every shard holds whole groups and the same K-slice of ``x`` its
    values rows contract against.  Partial outputs psum in f32 — the same
    reduce-scatter-free combine GSPMD derives for the sharded XLA einsum.
    """

    def body(x_, values_, indices_):
        _, fn = dispatch.resolve(
            "nm_spmm", inner_mode, b=x_.shape[0], k=x_.shape[-1],
            o=values_.shape[-1], n=n, m=m, shards=1,
        )
        y = fn(x_, values_, indices_, n, m, o_true=o_true).astype(jnp.float32)
        return jax.lax.psum(y, MODEL_AXIS).astype(x_.dtype)

    return shard_map(
        body, mesh,
        in_specs=(P(None, MODEL_AXIS), P(MODEL_AXIS, None), P(MODEL_AXIS, None)),
        out_specs=P(),
        check_rep=False,
    )(x, values, indices)


dispatch.register("paged_attn", "shard_map", paged_attn_shard_map)
dispatch.register("nm_spmm", "shard_map", nm_spmm_shard_map)

# Divisibility guards: the wrappers' in_specs split operand dims exactly.
# Call sites that predate the route (no num_pages in their shape info)
# fail the paged-attn guard and keep the XLA backstop.
dispatch.register_shard_guard(
    "paged_attn",
    lambda **kw: kw.get("num_pages", 0) > 0
    and kw["num_pages"] % kw.get("shards", 1) == 0,
)
dispatch.register_shard_guard(
    "nm_spmm",
    lambda **kw: kw.get("k", 0) > 0
    and kw["k"] % (kw["m"] * kw.get("shards", 1)) == 0,
)
