# Pallas TPU kernels for the paper's compute hot-spots:
#   nm_mask — fused per-step N:M mask computation + application (training)
#   nm_spmm — compressed N:M matmul (serving; HBM-bandwidth win, DESIGN.md §3)
# ops.py holds the jit'd public wrappers with CPU fallback; ref.py the
# pure-jnp oracles used by the allclose test sweeps.
from repro.kernels.ops import nm_mask_apply, nm_spmm, on_tpu
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
