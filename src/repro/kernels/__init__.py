# Pallas TPU kernels for the paper's compute hot-spots:
#   nm_mask    — fused per-step N:M mask computation + application (training)
#   nm_spmm    — compressed N:M matmul (serving; HBM-bandwidth win, DESIGN.md §3)
#   paged_attn — paged decode attention walking the KV page table directly
# dispatch.py is the single routing point (Pallas-TPU / Pallas-interpret /
# vectorized XLA, by backend + shape + override); ops.py holds the legacy
# jit'd wrappers; ref.py the pure-jnp oracles for the allclose test sweeps.
from repro.kernels import dispatch
from repro.kernels.ops import nm_mask_apply, nm_spmm, on_tpu
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas, nm_spmm_xla
from repro.kernels.paged_attn import paged_attn_pallas, paged_attn_xla
