"""Pallas TPU kernel: fused N:M mask computation + application.

The per-step hot-spot of every STE-family recipe is re-deriving the N:M mask
of every weight tensor from its current magnitudes (paper Eq. 8: Π_t is
recomputed from w_t each step). The pure-jnp path (top_k + scatter) lowers to
a sort plus several weight-sized HBM intermediates; this kernel streams each
weight tile through VMEM exactly once and emits (Π⊙w, Π) with no extra HBM
round-trips.

Algorithm (inside one (TR, TC) VMEM block, groups of M running down rows —
axis 0 is the matmul reduction axis, matching ``core.masking``):
reshape to (G, M, TC); then N rounds of iterative argmax per (group, col):
mark the largest unselected |w|, deterministic lowest-index tie-break via a
row-iota argmin trick. N and M are compile-time constants, so the selection
loop fully unrolls into VPU ops — no sort network, no gather.

Block shapes: TR=256 rows (any multiple of M), TC=256 lanes (multiple of the
128-lane VREG). VMEM footprint/block: in + 2 outs + f32 scratch ≈
256·256·(2+2+2+4)B ≈ 640 KiB — comfortably inside the ~16 MiB/core budget,
leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dispatch


def _nm_mask_kernel(w_ref, masked_ref, mask_ref, *, n: int, m: int):
    w = w_ref[...]  # (TR, TC)
    tr, tc = w.shape
    g = tr // m
    aw = jnp.abs(w.astype(jnp.float32)).reshape(g, m, tc)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (g, m, tc), 1)
    selected = jnp.zeros((g, m, tc), jnp.bool_)
    for _ in range(n):  # unrolled: n is static
        cand = jnp.where(selected, -jnp.inf, aw)
        mx = jnp.max(cand, axis=1, keepdims=True)  # (G,1,TC)
        is_max = cand == mx
        # deterministic tie-break: lowest row index among the maxima
        pick = jnp.min(jnp.where(is_max, row_iota, m), axis=1, keepdims=True)
        selected = selected | (row_iota == pick)
    mask = selected.reshape(tr, tc)
    mask_ref[...] = mask.astype(w_ref.dtype)
    masked_ref[...] = jnp.where(mask, w, jnp.zeros_like(w))


@functools.partial(jax.jit, static_argnames=("n", "m", "block_r", "block_c", "interpret"))
def nm_mask_apply_pallas(
    w: jnp.ndarray,
    n: int,
    m: int,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (Π⊙w, Π) for a 2-D weight ``w`` with groups along axis 0.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on TPU pass ``interpret=False``.
    """
    assert w.ndim == 2, "kernel operates on 2-D matmul weights"
    r, c = w.shape
    assert r % m == 0, (r, m)
    br = min(block_r, r)
    br -= br % m or 0
    bc = min(block_c, c)
    # pad to block multiples (pallas grids need exact tiling)
    rp = -(-r // br) * br
    cp = -(-c // bc) * bc
    wp = jnp.pad(w, ((0, rp - r), (0, cp - c)))
    grid = (rp // br, cp // bc)
    masked, mask = pl.pallas_call(
        functools.partial(_nm_mask_kernel, n=n, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), w.dtype),
            jax.ShapeDtypeStruct((rp, cp), w.dtype),
        ],
        interpret=interpret,
    )(wp)
    return masked[:r, :c], mask[:r, :c]


# ---------------------------------------------------------------------------
# dispatch registration: nm_mask routes through the kernels.dispatch
# registry like nm_spmm / paged_attn (the legacy prefer_pallas/interpret
# knobs in kernels.ops are retired).  All three modes return (Π, Π⊙w).
# ---------------------------------------------------------------------------


def _kernel_entry(w, n: int, m: int, *, interpret: bool):
    masked, mask = nm_mask_apply_pallas(w, n, m, interpret=interpret)
    return mask, masked


def _xla_entry(w, n: int, m: int):
    from repro.core import masking as ref_masking

    mask = ref_masking.nm_mask(w, n, m, 0)
    return mask, mask * w


dispatch.register(
    "nm_mask", "pallas", functools.partial(_kernel_entry, interpret=False)
)
dispatch.register(
    "nm_mask", "interpret", functools.partial(_kernel_entry, interpret=True)
)
dispatch.register("nm_mask", "xla", _xla_entry)
# shape gating (2-D, whole N:M groups down the rows) lives in
# dispatch.nm_mask itself: it must override forced/env modes too, which a
# resolve()-level guard cannot, so keeping a guard here would just be a
# second stale copy of the same predicate
