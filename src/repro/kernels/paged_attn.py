"""Pallas paged decode-attention: the kernel walks the page table directly.

The PR-2 paged KV pool stored pages device-side but the decode step still
*gathered* every lane's logical ``(B, S_max, ...)`` view contiguous before
``layers.decode_attention`` — per step, per layer, the full logical cache
was rewritten through HBM.  This kernel consumes the pool and the page
table as-is: the grid's innermost dimension walks one lane's table slots,
each step's BlockSpec index map reads ``tables[b, p]`` (scalar-prefetched,
so the address is known before the body runs) and DMAs exactly that
physical page HBM→VMEM, and a flash-style online softmax accumulates across
pages in f32 VMEM scratch.  Sentinel (unmapped) slots clamp their DMA to a
resident page and skip all compute under ``pl.when``; sliding-window lanes
visit only slots whose logical page intersects the live window.

Bytes per decode step (the quantity this kernel exists to shrink; measured
fields ``kv_bytes_per_step`` / ``bytes_read_per_step`` in
``BENCH_serve.json`` and ``kv_byte_ratio`` in ``BENCH_paged_attn.json``):
the gathered path materializes every lane's full ``S_max`` logical view
per layer per step; the kernel reads each lane's ``ceil(len/ps)`` live
pages once.  Measured: the slab-vs-paged serve sweep averages ~24.3 KB of
live KV per step (up to 5 concurrent heterogeneous lanes) where the
gathered view is ~328 KB — a 13x byte gap — and the
``kernel_bench`` paged-attn cases at 12.5–25% occupancy read 0.156x–0.312x
of the gathered bytes.  The gap widens linearly with ``S_max / len``.

Operand contract (kernel layout — callers reshape, see
``models.cache.PagedLayout.attn_decode`` / ``models.mla.mla_decode``):

    q         (B, Hkv, G, D)   queries grouped per KV head
    k_pages   (P, ps, Hkv, D)  physical pool (P = num_pages, sentinel = P)
    v_pages   (P, ps, Hkv, Dv) pool; pass ``v_is_k=True`` to reuse
                               ``k_pages`` (MLA: V *is* the latent)
    tables    (B, n_slots) int32 page table; slot value P means unmapped
    lengths   (B,)        int32 live tokens per lane (pos + 1)
    q2/k2_pages            optional second score stream, added into the
                           logits pre-softmax (MLA: the RoPE key part)
    window/win_slots       sliding-window width and modular table slots;
                           slot ``s`` holds logical page ``pg`` with
                           ``pg ≡ s (mod win_slots)``

Two shapes cover the zoo:

- **GQA**: ``G = H // Hkv``, ``D = Dv = head_dim``.
- **MLA-latent** (absorbed decode): ``Hkv = 1``, ``G = H``,
  ``D = kv_lora``, ``q2/k2`` carry the shared RoPE key, ``v_is_k=True``
  so the latent pool is streamed once and ``o = p @ c_kv`` comes back in
  latent space (the caller up-projects with the absorbed ``W_uv``).

``paged_attn_xla`` is the parity oracle: the same masking math on the
table-gathered view (it *does* materialize ``(B, n_slots·ps, ...)`` — that
is the point of reference, not a production route).  Accumulation order
differs (per-page flash vs one softmax), so parity is fp-tolerance, not
bit-level; see ``tests/test_paged_attn.py`` for the locked tolerances.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import dispatch

_NEG = -1e30  # finite -inf stand-in: keeps masked lanes exp()-safe


def _paged_attn_kernel(
    tables_ref,  # (B, n_slots) int32, scalar-prefetched
    lengths_ref,  # (B,) int32, scalar-prefetched
    *refs,
    page_size: int,
    window: int,
    win_slots: int,
    scale: float,
    sentinel: int,
    has_k2: bool,
    has_scale: bool,
    v_is_k: bool,
    emit_stats: bool,
):
    it = iter(refs)
    q_ref = next(it)
    q2_ref = next(it) if has_k2 else None
    k_ref = next(it)
    ks_ref = next(it) if has_scale else None
    k2_ref = next(it) if has_k2 else None
    k2s_ref = next(it) if (has_k2 and has_scale) else None
    v_ref = k_ref if v_is_k else next(it)
    vs_ref = None if v_is_k else (next(it) if has_scale else None)
    o_ref = next(it)
    m_ref = next(it) if emit_stats else None
    l_ref = next(it) if emit_stats else None
    m_scr, l_scr, acc_scr = it

    b, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    phys = tables_ref[b, p]
    ps = page_size
    if window:
        # modular table: slot p holds the newest logical page ≡ p (mod slots)
        cur_pg = jnp.maximum(length - 1, 0) // ps
        pg = cur_pg - jnp.mod(cur_pg - p, win_slots)
        lo = jnp.maximum(length - window, 0)
    else:
        pg = p
        lo = 0
    base = pg * ps
    live = (
        (phys != sentinel)
        & (length > 0)
        & (base < length)
        & (base + ps > lo)
    )
    if window:
        live &= pg >= 0  # slot not yet reached by this lane

    @pl.when(live)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (ps, D)
        if has_scale:
            # int8 pages: per-(page, slot) scales dequantize in VMEM, so
            # HBM only ever streams the 1-byte codes
            k = k * ks_ref[0, :, 0, :]  # (ps, 1) f32
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, ps)
        if has_k2:
            q2 = q2_ref[0, 0].astype(jnp.float32)
            k2 = k2_ref[0, :, 0, :].astype(jnp.float32)
            if has_scale:
                k2 = k2 * k2s_ref[0, :, 0, :]
            s = s + jax.lax.dot_general(
                q2, k2, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        s = s * scale
        apos = base + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        ok = (apos < length) & (apos >= lo)
        s = jnp.where(ok, s, _NEG)
        m_prev = m_scr[:, :1]  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_scr[:, :1] + jnp.sum(pexp, axis=-1, keepdims=True)
        if v_is_k:
            v = k  # (ps, Dv) — already dequantized above
        else:
            v = v_ref[0, :, 0, :].astype(jnp.float32)  # (ps, Dv)
            if has_scale:
                v = v * vs_ref[0, :, 0, :]
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(p == pl.num_programs(2) - 1)
    def _flush():
        if emit_stats:
            # raw flash stats: the shard_map wrapper renormalizes across
            # shards (pmax the maxima, psum the corrected l and acc)
            o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
            m_ref[0, 0] = m_scr[...].astype(m_ref.dtype)
            l_ref[0, 0] = l_scr[...].astype(l_ref.dtype)
        else:
            # dead lanes (l == 0) flush exact zeros, not NaNs
            o_ref[0, 0] = (
                acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
            ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "window", "win_slots", "v_is_k", "interpret", "emit_stats",
    ),
)
def paged_attn_pallas(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k_pages: jnp.ndarray,  # (P, ps, Hkv, D)
    v_pages: Optional[jnp.ndarray],  # (P, ps, Hkv, Dv) or None when v_is_k
    tables: jnp.ndarray,  # (B, n_slots) int32
    lengths: jnp.ndarray,  # (B,) int32
    *,
    scale: float,
    window: int = 0,
    win_slots: int = 0,
    q2: Optional[jnp.ndarray] = None,  # (B, Hkv, G, D2)
    k2_pages: Optional[jnp.ndarray] = None,  # (P, ps, Hkv, D2)
    k_scale: Optional[jnp.ndarray] = None,  # (P, ps) int8-page scales
    v_scale: Optional[jnp.ndarray] = None,  # (P, ps)
    k2_scale: Optional[jnp.ndarray] = None,  # (P, ps)
    v_is_k: bool = False,
    interpret: bool = False,
    emit_stats: bool = False,
) -> jnp.ndarray:
    """Fused paged decode attention; returns ``(B, Hkv, G, Dv)``.

    Grid ``(B, Hkv, n_slots)`` with the table slot innermost; page blocks
    are addressed through the scalar-prefetched table so only mapped pages
    move HBM→VMEM (consecutive sentinel slots clamp to the same resident
    page and re-use the previous DMA).

    int8 pools pass ``k_scale``/``v_scale`` (``k2_scale`` for the RoPE
    stream; ``v_is_k`` reuses ``k_scale``): per-(page, slot) scales (any
    fp dtype; upcast to f32) that ride the same table-addressed DMA and
    dequantize each page in VMEM before the dot — identical flash math, 1-byte HBM traffic.

    With ``emit_stats=True`` the normalization is skipped and the raw
    flash triple ``(acc, m, l)`` comes back in f32 — ``acc`` is the
    unnormalized ``(B, Hkv, G, Dv)`` accumulator, ``m``/``l`` the running
    max/denominator ``(B, Hkv, G)``.  The shard_map wrapper combines these
    across pool shards before dividing (``kernels.sharded.combine_stats``).
    """
    b, hkv, g, d = q.shape
    p_pages, ps = k_pages.shape[0], k_pages.shape[1]
    n_slots = tables.shape[1]
    has_k2 = q2 is not None
    has_scale = k_scale is not None
    dv = d if v_is_k else v_pages.shape[-1]

    def q_index(b_, h_, p_, tables_, lengths_):
        return (b_, h_, 0, 0)

    def page_index(b_, h_, p_, tables_, lengths_):
        return (jnp.minimum(tables_[b_, p_], p_pages - 1), 0, h_, 0)

    def scale_index(b_, h_, p_, tables_, lengths_):
        # scales have no head axis: (P, ps, 1, 1) blocks pin dims 2/3 to 0
        return (jnp.minimum(tables_[b_, p_], p_pages - 1), 0, 0, 0)

    def scale_spec():
        return pl.BlockSpec((1, ps, 1, 1), scale_index)

    def scale_op(s):
        return s.astype(jnp.float32).reshape(p_pages, ps, 1, 1)

    in_specs = [pl.BlockSpec((1, 1, g, d), q_index)]
    operands = [q]
    if has_k2:
        in_specs.append(pl.BlockSpec((1, 1, g, q2.shape[-1]), q_index))
        operands.append(q2)
    in_specs.append(pl.BlockSpec((1, ps, 1, d), page_index))
    operands.append(k_pages)
    if has_scale:
        in_specs.append(scale_spec())
        operands.append(scale_op(k_scale))
    if has_k2:
        in_specs.append(pl.BlockSpec((1, ps, 1, k2_pages.shape[-1]), page_index))
        operands.append(k2_pages)
        if has_scale:
            in_specs.append(scale_spec())
            operands.append(scale_op(k2_scale))
    if not v_is_k:
        in_specs.append(pl.BlockSpec((1, ps, 1, dv), page_index))
        operands.append(v_pages)
        if has_scale:
            in_specs.append(scale_spec())
            operands.append(scale_op(v_scale))

    if emit_stats:
        # m/l leave as 128-wide lane-aligned blocks, sliced outside
        out_shape = [
            jax.ShapeDtypeStruct((b, hkv, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
        ]
        out_specs = [
            pl.BlockSpec((1, 1, g, dv), q_index),
            pl.BlockSpec((1, 1, g, 128), q_index),
            pl.BlockSpec((1, 1, g, 128), q_index),
        ]
    else:
        out_shape = jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype)
        out_specs = pl.BlockSpec((1, 1, g, dv), q_index)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_slots),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),  # running max
            pltpu.VMEM((g, 128), jnp.float32),  # running denominator
            pltpu.VMEM((g, dv), jnp.float32),  # output accumulator
        ],
    )
    kernel = functools.partial(
        _paged_attn_kernel,
        page_size=ps,
        window=window,
        win_slots=win_slots,
        scale=scale,
        sentinel=p_pages,
        has_k2=has_k2,
        has_scale=has_scale,
        v_is_k=v_is_k,
        emit_stats=emit_stats,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    if emit_stats:
        acc, mm, ll = out
        return acc, mm[..., 0], ll[..., 0]
    return out


# ---------------------------------------------------------------------------
# XLA oracle: identical masking math on the table-gathered view
# ---------------------------------------------------------------------------


def _gathered_stats(
    q, k_pages, v_pages, tables, lengths, *,
    scale, window, win_slots, q2, k2_pages, v_is_k,
    k_scale=None, v_scale=None, k2_scale=None,
):
    """Gathered masking math in unnormalized-stats form: ``(acc, m, l)``
    f32 with ``acc = (B, Hkv, G, Dv)``, ``m``/``l`` ``(B, Hkv, G)``.
    Shared by the normalized oracle and the stats entry the shard_map
    wrapper's XLA inner route uses."""
    b, hkv, g, d = q.shape
    p_pages, ps = k_pages.shape[0], k_pages.shape[1]
    n_slots = tables.shape[1]
    lengths = lengths.reshape(b, 1).astype(jnp.int32)
    slot = jnp.arange(n_slots)[None, :]  # (1, S)
    if window:
        cur_pg = jnp.maximum(lengths - 1, 0) // ps
        pg = cur_pg - jnp.mod(cur_pg - slot, win_slots)
        lo = jnp.maximum(lengths - window, 0)
    else:
        pg = jnp.broadcast_to(slot, (b, n_slots))
        lo = jnp.zeros((b, 1), jnp.int32)
    base = pg * ps
    apos = base[..., None] + jnp.arange(ps)[None, None, :]  # (B, S, ps)
    valid = (
        (apos < lengths[..., None])
        & (apos >= lo[..., None])
        & (tables[..., None] != p_pages)
        & (pg[..., None] >= 0)
    )
    phys = jnp.minimum(tables, p_pages - 1)  # (B, S)

    def deq(pages, sc):
        g_ = pages[phys].astype(jnp.float32)  # (B, S, ps, Hkv, D) — the gather
        if sc is not None:
            g_ = g_ * sc[phys].astype(jnp.float32)[..., None, None]
        return g_

    kg = deq(k_pages, k_scale)
    s = jnp.einsum("bhgd,bsphd->bhgsp", q.astype(jnp.float32), kg)
    if q2 is not None:
        s = s + jnp.einsum(
            "bhgd,bsphd->bhgsp", q2.astype(jnp.float32), deq(k2_pages, k2_scale)
        )
    s = jnp.where(valid[:, None, None], s * scale, _NEG)
    m = jnp.max(s, axis=(-2, -1))  # (B, Hkv, G); _NEG on dead lanes
    pexp = jnp.exp(s - m[..., None, None]) * valid[:, None, None]
    l = jnp.sum(pexp, axis=(-2, -1))
    vg = kg if v_is_k else deq(v_pages, v_scale)
    acc = jnp.einsum("bhgsp,bsphd->bhgd", pexp, vg)
    return acc, m, l


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "win_slots", "v_is_k")
)
def paged_attn_xla(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: Optional[jnp.ndarray],
    tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: float,
    window: int = 0,
    win_slots: int = 0,
    q2: Optional[jnp.ndarray] = None,
    k2_pages: Optional[jnp.ndarray] = None,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    k2_scale: Optional[jnp.ndarray] = None,
    v_is_k: bool = False,
) -> jnp.ndarray:
    """Gathered reference: materializes the ``(B, n_slots·ps, ...)`` view
    (exactly what the kernel exists to avoid) and applies the same
    per-position masks.  Parity oracle + off-TPU fallback for callers that
    already hold kernel-layout operands."""
    acc, m, l = _gathered_stats(
        q, k_pages, v_pages, tables, lengths, scale=scale, window=window,
        win_slots=win_slots, q2=q2, k2_pages=k2_pages, v_is_k=v_is_k,
        k_scale=k_scale, v_scale=v_scale, k2_scale=k2_scale,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "win_slots", "v_is_k")
)
def paged_attn_stats_xla(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: Optional[jnp.ndarray],
    tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: float,
    window: int = 0,
    win_slots: int = 0,
    q2: Optional[jnp.ndarray] = None,
    k2_pages: Optional[jnp.ndarray] = None,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    k2_scale: Optional[jnp.ndarray] = None,
    v_is_k: bool = False,
):
    """Stats-form gathered path: same math as :func:`paged_attn_xla` with
    the final divide left to the caller (the shard_map combine)."""
    return _gathered_stats(
        q, k_pages, v_pages, tables, lengths, scale=scale, window=window,
        win_slots=win_slots, q2=q2, k2_pages=k2_pages, v_is_k=v_is_k,
        k_scale=k_scale, v_scale=v_scale, k2_scale=k2_scale,
    )


dispatch.register(
    "paged_attn", "pallas", functools.partial(paged_attn_pallas, interpret=False)
)
dispatch.register(
    "paged_attn", "interpret", functools.partial(paged_attn_pallas, interpret=True)
)
dispatch.register("paged_attn", "xla", paged_attn_xla)

# stats-emitting variant: the per-shard inner kernel of the shard_map route
# (kernels.sharded).  Same grid walk; normalization deferred to the combine.
dispatch.register(
    "paged_attn_stats", "pallas",
    functools.partial(paged_attn_pallas, interpret=False, emit_stats=True),
)
dispatch.register(
    "paged_attn_stats", "interpret",
    functools.partial(paged_attn_pallas, interpret=True, emit_stats=True),
)
dispatch.register("paged_attn_stats", "xla", paged_attn_stats_xla)
