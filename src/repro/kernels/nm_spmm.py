"""Compressed N:M structured-sparse matmul (serving decode path).

TPUs have no Sparse-Tensor-Core analogue, but decode is HBM-bandwidth-bound:
the win from a learned N:M mask on TPU is reading only the kept N/M of the
weights from HBM (DESIGN.md §3).  The Pallas kernel streams compressed
tiles — values ``(K·N/M, O)`` + 8-bit in-group indices — into VMEM,
decompresses the tile *inside* VMEM with unrolled select ops, and feeds the
dense MXU.

Bandwidth model (per weight, measured on the ``gpt2-paper`` smoke artifact
via ``benchmarks/serve_bench.py`` — see ``weight_bytes_per_step`` in
``BENCH_serve.json``):

    HBM weight traffic per tile:  (N/M)·(bits_w + 8)/bits_w of dense
    (2:4 bf16: 0.75x;  1:4: 0.375x;  2:8 int8 would be 0.5x)

    gpt2-paper smoke, 2:4 bf16: 210_944 weight bytes/decode-step compressed
    vs 268_288 dense (0.786x — embeddings stay dense; matmul weights alone
    are 0.75x).  The same ratio bounds the achievable decode-step speedup
    at batch 1, where weight streaming dominates the step.  On the CPU
    bench the dispatch fix alone flipped compressed decode from 8.2x
    *slower* than dense (14_492 µs vs 1_764 µs/step, the seed pathology)
    to parity-or-faster at batch 1 (1_927 vs 2_180 µs and 1_377 vs
    1_308 µs across runs) and within 2x at batches 2-4.

Routing (see ``kernels.dispatch``): the compiled kernel serves TPU; CPU/GPU
use :func:`nm_spmm_xla` below.  Nothing in the hot loop runs the Pallas
interpreter — the seed's ``interpret=True`` default was how compressed
decode measured ~8x slower than dense on CPU.

Pallas schedule: grid (i, j, k) over (rows of x / BM, cols of W / BO,
reduction / BK) with a f32 VMEM accumulator; k is the innermost
(sequential) dimension and the accumulator is flushed at k == K-1 — the
standard Pallas TPU matmul schedule.  Blocks: BM=128, BO=256, BK=512
dense-rows (=> 512·N/M compressed rows), MXU-aligned.  Block sizes are
picked by gcd (no decrement-until-divides scan), and ``values``/``indices``
are expected pre-padded to lane alignment by ``sparse_infer.
compress_params`` — the runtime ``jnp.pad`` survives only as a fallback for
ad-hoc (test) shapes and artifacts compressed without TPU alignment (see
``compress_params(align=...)`` for the cross-backend export caveat); a
TPU-exported artifact never re-pads per call.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import dispatch


def pick_bk(k: int, n: int, m: int, target: int = 512) -> int:
    """Reduction block size: a divisor of ``k`` that keeps the compressed
    row count ``bk·n/m`` integral, picked via gcd in O(1).

    ``bk·n % m == 0``  iff  ``bk % (m / gcd(n, m)) == 0``; with ``q`` that
    quotient, valid block sizes are exactly the multiples of ``q`` dividing
    ``k``, and the pick is ``q · gcd(k/q, target/q)`` — no decrementing
    scan, and no near-prime ``bk`` that a scan could land on.  Shapes whose
    best pick is still tiny are routed to the XLA path by the dispatch
    guard instead of running a degenerate grid.
    """
    q = m // math.gcd(n, m)
    if k % q:
        raise ValueError(f"k={k} not divisible by m/gcd(n,m)={q}")
    return q * math.gcd(k // q, max(target // q, 1))


def _pick_block(dim: int, target: int) -> int:
    """Lane-dim block size: a gcd-divisor of ``dim`` when one of MXU size
    exists (no runtime pad), else ``target`` itself — a non-divisor, which
    makes the caller pad ``dim`` up.  Keeps unaligned ad-hoc widths (e.g. a
    vocab head) on the Pallas route at the cost of the pad the exported,
    compress-time-aligned artifacts never pay."""
    if dim <= target:
        return dim
    g = math.gcd(dim, target)
    return g if g >= 128 else target


def pallas_shape_ok(b: int, k: int, o: int, n: int, m: int) -> bool:
    """Dispatch guard: can the Pallas grid tile this shape non-degenerately?

    Requires whole groups along the reduction dim and a reduction block of
    at least one MXU tile (128) — smaller picks mean a pathological K
    (e.g. 2·prime) that the XLA path handles better than a bk=2 grid
    would.  The output dim never rejects: unaligned widths fall back to a
    runtime pad inside :func:`nm_spmm_pallas`.
    """
    return k % m == 0 and pick_bk(k, n, m) >= min(k, 128)


def _nm_spmm_kernel(x_ref, v_ref, i_ref, o_ref, acc_ref, *, n: int, m: int, bk: int):
    """x (BM, BK) @ decompress(v, i) (BK, BO) -> o (BM, BO)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    vals = v_ref[...].astype(jnp.float32)  # (BK*n/m, BO)
    idx = i_ref[...].astype(jnp.int32)
    g = bk // m  # dense groups in this block
    bo = vals.shape[-1]
    valsg = vals.reshape(g, n, bo)
    idxg = idx.reshape(g, n, bo)
    # decompress in VMEM: dense[g, r, o] = sum_j (idx[g, j, o] == r) * vals[g, j, o]
    row = jax.lax.broadcasted_iota(jnp.int32, (g, m, bo), 1)
    dense = jnp.zeros((g, m, bo), jnp.float32)
    for j in range(n):  # unrolled: n is static
        dense = dense + jnp.where(
            idxg[:, j : j + 1, :] == row, valsg[:, j : j + 1, :], 0.0
        )
    w = dense.reshape(bk, bo)
    acc_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "bm", "bo", "bk", "o_true", "interpret"),
)
def nm_spmm_pallas(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K*n/m, O) — O pre-padded to lane alignment
    indices: jnp.ndarray,  # (K*n/m, O) uint8
    n: int,
    m: int,
    bm: int = 128,
    bo: int = 256,
    bk: int = 512,
    o_true: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = x @ decompress(values, indices); compressed weights never
    materialize densely in HBM.

    ``values``/``indices`` arrive MXU-aligned from compress time (see
    ``sparse_infer.compress_params``): block sizes are gcd-picks that
    divide the padded dims exactly, so no operand is re-padded here per
    call.  ``o_true`` strips the alignment columns from the result.
    """
    b, k = x.shape
    kc, o = values.shape
    assert kc * m == k * n, (k, kc, n, m)
    o_true = o if o_true is None else o_true
    bm = min(bm, b)
    bk = pick_bk(k, n, m, min(bk, k))
    bo = _pick_block(o, bo)
    bp = -(-b // bm) * bm
    op = -(-o // bo) * bo
    xp = jnp.pad(x, ((0, bp - b), (0, 0))) if bp != b else x
    if op != o:  # fallback for ad-hoc shapes; exported artifacts are aligned
        values = jnp.pad(values, ((0, 0), (0, op - o)))
        indices = jnp.pad(indices, ((0, 0), (0, op - o)))
    bkc = bk * n // m  # compressed rows per block
    grid = (bp // bm, op // bo, k // bk)
    out = pl.pallas_call(
        functools.partial(_nm_spmm_kernel, n=n, m=m, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkc, bo), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bkc, bo), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, op), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32)],
        interpret=interpret,
    )(xp, values, indices)
    return out[:b, :o_true]


# ---------------------------------------------------------------------------
# XLA production path (CPU / GPU) — satellite of the dispatch refactor
# ---------------------------------------------------------------------------

# Below this many rows the activation-gather formulation beats
# decompress+matmul (CPU, 2:4 f32: at (1, 1024, 1024) gather 2.7ms vs
# decompress 4.2ms; the gather scales with rows and loses by ~20x at
# b=8 on 2048^2, where decompress+BLAS takes over).  Off-TPU the point is
# bounded damage, not a win: at serving-bench sizes compressed decode now
# matches-or-beats dense at batch 1 and stays within 2x above
# (BENCH_serve.json), while at >=1024^2 single-row
# shapes both formulations pay ~one decompress of traffic vs a GEMV —
# the bandwidth *win* needs the TPU kernel, which never decompresses to
# HBM at all.
GATHER_ROWS = 8


@functools.partial(jax.jit, static_argnames=("n", "m", "o_true"))
def nm_spmm_xla(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K*n/m, O)
    indices: jnp.ndarray,  # (K*n/m, O) uint8
    n: int,
    m: int,
    o_true: int | None = None,
) -> jnp.ndarray:
    """Vectorized XLA compressed matmul — the production path off-TPU.

    Two regimes, chosen by (static) row count:

    - **decode** (``B <= GATHER_ROWS``): gather the activations each kept
      weight multiplies — ``x[b, g·m + idx[g,j,o]]`` — and reduce against
      ``values`` directly.  The dense weight is never materialized and the
      FLOP count is ~``3·(N/M)`` of the dense matmul (for 2:4 *fewer* ops
      than dense: this is what restores compressed-faster-than-dense on
      CPU, where the seed's scatter-decompress ref ran ~8x slower).
    - **prefill** (``B > GATHER_ROWS``): decompress with ``n`` unrolled
      compare/selects (the same schedule the Pallas kernel uses in VMEM)
      and hand the dense block to one BLAS matmul.

    Replaces ``put_along_axis`` decompression (XLA scatter: ~15x slower
    than either regime on CPU) everywhere except the oracle in ``ref.py``.
    """
    b, k = x.shape
    kc, o = values.shape
    assert kc * m == k * n, (k, kc, n, m)
    g = k // m
    o_true = o if o_true is None else o_true
    idx = indices.astype(jnp.int32).reshape(g, n, o)
    vals = values.astype(jnp.float32).reshape(g, n, o)
    if b <= GATHER_ROWS:
        xg = x.reshape(b, g, m)
        xsel = xg[:, jnp.arange(g)[:, None, None], idx]  # (B, g, n, O) gather
        y = jnp.einsum("bgno,gno->bo", xsel.astype(jnp.float32), vals)
    else:
        row = jax.lax.broadcasted_iota(jnp.int32, (g, m, o), 1)
        dense = jnp.zeros((g, m, o), jnp.float32)
        for j in range(n):  # unrolled: n is static
            dense = dense + jnp.where(
                idx[:, j : j + 1, :] == row, vals[:, j : j + 1, :], 0.0
            )
        y = x.astype(jnp.float32) @ dense.reshape(k, o)
    return y[:, :o_true].astype(x.dtype)


def _pallas_entry(x, values, indices, n, m, o_true=None, *, interpret):
    return nm_spmm_pallas(
        x, values, indices, n, m, o_true=o_true, interpret=interpret
    )


dispatch.register(
    "nm_spmm", "pallas", functools.partial(_pallas_entry, interpret=False)
)
dispatch.register(
    "nm_spmm", "interpret", functools.partial(_pallas_entry, interpret=True)
)
dispatch.register("nm_spmm", "xla", nm_spmm_xla)
dispatch.register_guard(
    "nm_spmm", lambda b, k, o, n, m, **_: pallas_shape_ok(b, k, o, n, m)
)
