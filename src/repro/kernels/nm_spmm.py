"""Pallas TPU kernel: compressed N:M structured-sparse matmul (decode path).

TPUs have no Sparse-Tensor-Core analogue, but decode is HBM-bandwidth-bound:
the win from a learned N:M mask on TPU is reading only the kept N/M of the
weights from HBM (DESIGN.md §3). The kernel streams compressed tiles —
values ``(K·N/M, O)`` + 8-bit in-group indices — into VMEM, decompresses the
tile *inside* VMEM with unrolled select ops, and feeds the dense MXU:

    HBM traffic per weight tile:  (N/M)·(bits_w + 8)/bits_w of dense
    (2:4 bf16: 0.75x;  1:4: 0.375x;  2:8 int8 would be 0.5x)

Grid (i, j, k) over (rows of x / BM, cols of W / BO, reduction / BK) with a
f32 VMEM accumulator; k is the innermost (sequential) dimension and the
accumulator is flushed at k == K-1 — the standard Pallas TPU matmul schedule.
Blocks: BM=128, BO=256, BK=512 dense-rows (=> 512·N/M compressed rows),
MXU-aligned (multiples of 128 on the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _nm_spmm_kernel(x_ref, v_ref, i_ref, o_ref, acc_ref, *, n: int, m: int, bk: int):
    """x (BM, BK) @ decompress(v, i) (BK, BO) -> o (BM, BO)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    vals = v_ref[...].astype(jnp.float32)  # (BK*n/m, BO)
    idx = i_ref[...].astype(jnp.int32)
    g = bk // m  # dense groups in this block
    bo = vals.shape[-1]
    valsg = vals.reshape(g, n, bo)
    idxg = idx.reshape(g, n, bo)
    # decompress in VMEM: dense[g, r, o] = sum_j (idx[g, j, o] == r) * vals[g, j, o]
    row = jax.lax.broadcasted_iota(jnp.int32, (g, m, bo), 1)
    dense = jnp.zeros((g, m, bo), jnp.float32)
    for j in range(n):  # unrolled: n is static
        dense = dense + jnp.where(
            idxg[:, j : j + 1, :] == row, valsg[:, j : j + 1, :], 0.0
        )
    w = dense.reshape(bk, bo)
    acc_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "bm", "bo", "bk", "interpret"),
)
def nm_spmm_pallas(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K*n/m, O)
    indices: jnp.ndarray,  # (K*n/m, O) uint8
    n: int,
    m: int,
    bm: int = 128,
    bo: int = 256,
    bk: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ decompress(values, indices); compressed weights never
    materialize densely in HBM."""
    b, k = x.shape
    kc, o = values.shape
    assert kc * m == k * n, (k, kc, n, m)
    bm = min(bm, b)
    bk = min(bk, k)
    while k % bk or (bk * n) % m:
        bk -= 1
    bo = min(bo, o)
    bp = -(-b // bm) * bm
    op = -(-o // bo) * bo
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    vp = jnp.pad(values, ((0, 0), (0, op - o)))
    ip = jnp.pad(indices, ((0, 0), (0, op - o)))
    bkc = bk * n // m  # compressed rows per block
    grid = (bp // bm, op // bo, k // bk)
    out = pl.pallas_call(
        functools.partial(_nm_spmm_kernel, n=n, m=m, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkc, bo), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bkc, bo), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, op), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32)],
        interpret=interpret,
    )(xp, vp, ip)
    return out[:b, :o]
