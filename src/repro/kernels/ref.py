"""Pure-jnp oracles for the Pallas kernels (the ``ref`` in kernel tests).

These re-export / compose the reference implementations in ``repro.core.
masking`` so the kernel tests have a single import point.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.masking import (
    nm_compress,
    nm_decompress,
    nm_mask,
    nm_mask_and_apply,
)

__all__ = [
    "nm_mask",
    "nm_mask_and_apply",
    "nm_compress",
    "nm_decompress",
    "nm_spmm_ref",
]


def nm_spmm_ref(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K*n/m, O)
    indices: jnp.ndarray,  # (K*n/m, O) uint8
    n: int,
    m: int,
) -> jnp.ndarray:
    """Oracle for the compressed N:M matmul: decompress then dense matmul."""
    w = nm_decompress(values, indices, n, m, group_axis=0)  # (K, O)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
