"""Pure-jnp oracles for the Pallas kernels (the ``ref`` in kernel tests).

These re-export / compose the reference implementations in ``repro.core.
masking`` so the kernel tests have a single import point.
``paged_attn_ref`` is the dense oracle for the paged decode-attention
family: gather-everything + one softmax, no flash decomposition, no page
walking — deliberately the dumbest correct program, so the Pallas / XLA /
shard_map twins (and the int8 per-page dequantization they share) have an
independent yardstick.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import (
    nm_compress,
    nm_decompress,
    nm_mask,
    nm_mask_and_apply,
)

__all__ = [
    "nm_mask",
    "nm_mask_and_apply",
    "nm_compress",
    "nm_decompress",
    "nm_spmm_ref",
    "paged_attn_ref",
]


def nm_spmm_ref(
    x: jnp.ndarray,  # (B, K)
    values: jnp.ndarray,  # (K*n/m, O)
    indices: jnp.ndarray,  # (K*n/m, O) uint8
    n: int,
    m: int,
) -> jnp.ndarray:
    """Oracle for the compressed N:M matmul: decompress then dense matmul."""
    w = nm_decompress(values, indices, n, m, group_axis=0)  # (K, O)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def _dequant_pages(
    pages: jnp.ndarray,  # (P, ps, Hkv, D) — fp or int8
    scale: Optional[jnp.ndarray],  # (P, ps) f32 or None
) -> jnp.ndarray:
    x = pages.astype(jnp.float32)
    if scale is not None:
        x = x * scale.astype(jnp.float32)[..., None, None]
    return x


def paged_attn_ref(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k_pages: jnp.ndarray,  # (P, ps, Hkv, D)
    v_pages: Optional[jnp.ndarray],  # (P, ps, Hkv, Dv); None when v_is_k
    tables: jnp.ndarray,  # (B, n_slots) int32, append-only, sentinel = P
    lengths: jnp.ndarray,  # (B,) int32
    *,
    scale: float,
    q2: Optional[jnp.ndarray] = None,
    k2_pages: Optional[jnp.ndarray] = None,
    v_is_k: bool = False,
    k_scale: Optional[jnp.ndarray] = None,  # (P, ps) per-page-row scales
    v_scale: Optional[jnp.ndarray] = None,
    k2_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Dense oracle for paged decode attention over *append-only* tables.

    Gathers every table slot into a contiguous logical view (slot ``p``
    holds positions ``[p*ps, (p+1)*ps)``; the sentinel gathers a zero
    page, dead under the length mask), optionally dequantizing int8 pages
    with their per-page-row scales, then runs one masked softmax in f32.
    Windowed (modular) tables are out of scope — the oracle's job is the
    full-table math the prefix-cache / int8 paths build on.
    """
    b, hkv, g, d = q.shape
    p, ps = k_pages.shape[0], k_pages.shape[1]
    n_slots = tables.shape[1]
    s = n_slots * ps
    phys = jnp.clip(tables, 0, p)  # sentinel stays on the zero page

    def gather(pages, sc):
        x = _dequant_pages(pages, sc)
        x = jnp.concatenate([x, jnp.zeros_like(x[:1])], axis=0)  # zero page
        out = x[phys]  # (B, n_slots, ps, Hkv, Dx)
        return out.reshape(b, s, x.shape[2], x.shape[3])

    kg = gather(k_pages, k_scale)  # (B, S, Hkv, D)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32), kg
    ) * scale
    if q2 is not None:
        k2g = gather(k2_pages, k2_scale)
        logits = logits + jnp.einsum(
            "bhgd,bshd->bhgs", q2.astype(jnp.float32), k2g
        ) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(mask[:, None, None, :], w, 0.0)  # all-dead rows -> 0
    vg = kg if v_is_k else gather(v_pages, v_scale)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vg)
    return out.astype(q.dtype)
