"""jit'd public wrappers for the Pallas kernels.

Routing lives entirely in ``kernels.dispatch`` (backend + shape + override);
these wrappers keep the historical call signatures.  The legacy
``prefer_pallas``/``interpret`` knobs the seed threaded through every call
site are retired: callers that need to pin a route pass ``mode=`` (or use
``dispatch.force_mode`` / ``REPRO_KERNEL_MODE``), and everything else lets
the registry decide — Pallas on TPU, the vectorized XLA path elsewhere,
the interpreter only when explicitly forced for correctness checks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nm_mask_apply(
    w: jnp.ndarray, n: int, m: int, *, mode: Optional[str] = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return ``(Π, Π⊙w)`` — the mask, then the masked weight — via the
    fused kernel when profitable (``kernels.dispatch`` decides; 2-D weights
    with whole groups down axis 0 are kernel-eligible, everything else
    takes the reference path)."""
    return dispatch.nm_mask(w, n, m, mode=mode)


def nm_spmm(
    x: jnp.ndarray,
    values: jnp.ndarray,
    indices: jnp.ndarray,
    n: int,
    m: int,
    *,
    o_true: Optional[int] = None,
    shards: int = 1,
    mode: Optional[str] = None,
) -> jnp.ndarray:
    """Compressed N:M matmul (serving path), routed by ``kernels.dispatch``.

    Off-TPU this runs the vectorized XLA path (``nm_spmm_xla``) — never the
    Pallas interpreter, which is how the seed's compressed decode came in
    ~8x slower than dense on CPU.  ``shards`` (``CompressedTensor.rshards``)
    marks reduction-TP'd operands so sharded calls can take the per-shard
    shard_map route — see ``dispatch.nm_spmm``."""
    return dispatch.nm_spmm(
        x, values, indices, n, m, o_true=o_true, shards=shards, mode=mode
    )
