"""jit'd public wrappers for the Pallas kernels, with automatic fallback.

``use_pallas(...)`` decides per-platform: on TPU the compiled kernels run
natively; on CPU (this container) they run in interpret mode inside tests
and benchmarks, while the hot training path uses the jnp reference (the
kernels are the TPU *target*, not a CPU win).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import masking as ref_masking
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nm_mask_apply(
    w: jnp.ndarray,
    n: int,
    m: int,
    *,
    prefer_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return ``(Π, Π⊙w)`` — the mask, then the masked weight — via the
    fused kernel when profitable.

    2-D weights with groups on axis 0 route to Pallas; other ranks use the
    reference path (they are rare and small in the zoo)."""
    use = prefer_pallas if prefer_pallas is not None else on_tpu()
    if use and w.ndim == 2 and w.shape[0] % m == 0:
        itp = (not on_tpu()) if interpret is None else interpret
        masked, mask = nm_mask_apply_pallas(w, n, m, interpret=itp)
        return mask, masked
    mask = ref_masking.nm_mask(w, n, m, 0)
    return mask, mask * w


def nm_spmm(
    x: jnp.ndarray,
    values: jnp.ndarray,
    indices: jnp.ndarray,
    n: int,
    m: int,
    *,
    prefer_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Compressed N:M matmul (serving path)."""
    use = prefer_pallas if prefer_pallas is not None else on_tpu()
    if use:
        itp = (not on_tpu()) if interpret is None else interpret
        return nm_spmm_pallas(x, values, indices, n, m, interpret=itp)
    return ref.nm_spmm_ref(x, values, indices, n, m)
