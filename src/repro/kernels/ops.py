"""jit'd public wrappers for the Pallas kernels.

Routing lives in ``kernels.dispatch`` (backend + shape + override); these
wrappers keep the historical call signatures and translate the legacy
``prefer_pallas``/``interpret`` knobs onto dispatch modes.  ``nm_mask`` is
a training-time kernel and keeps its local TPU-or-reference switch until
it migrates into the registry (registered as "future nm_mask" there).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import masking as ref_masking
from repro.kernels import dispatch
from repro.kernels.nm_mask import nm_mask_apply_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _legacy_mode(
    prefer_pallas: Optional[bool], interpret: Optional[bool]
) -> Optional[str]:
    """Map the legacy knobs onto a dispatch mode (None = dispatch decides)."""
    if prefer_pallas is None:
        return None
    if not prefer_pallas:
        return "xla"
    itp = (not on_tpu()) if interpret is None else interpret
    return "interpret" if itp else "pallas"


def nm_mask_apply(
    w: jnp.ndarray,
    n: int,
    m: int,
    *,
    prefer_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return ``(Π, Π⊙w)`` — the mask, then the masked weight — via the
    fused kernel when profitable.

    2-D weights with groups on axis 0 route to Pallas; other ranks use the
    reference path (they are rare and small in the zoo)."""
    use = prefer_pallas if prefer_pallas is not None else on_tpu()
    if use and w.ndim == 2 and w.shape[0] % m == 0:
        itp = (not on_tpu()) if interpret is None else interpret
        masked, mask = nm_mask_apply_pallas(w, n, m, interpret=itp)
        return mask, masked
    mask = ref_masking.nm_mask(w, n, m, 0)
    return mask, mask * w


def nm_spmm(
    x: jnp.ndarray,
    values: jnp.ndarray,
    indices: jnp.ndarray,
    n: int,
    m: int,
    *,
    o_true: Optional[int] = None,
    prefer_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Compressed N:M matmul (serving path), routed by ``kernels.dispatch``.

    Off-TPU this runs the vectorized XLA path (``nm_spmm_xla``) — never the
    Pallas interpreter, which is how the seed's compressed decode came in
    ~8x slower than dense on CPU."""
    return dispatch.nm_spmm(
        x, values, indices, n, m, o_true=o_true,
        mode=_legacy_mode(prefer_pallas, interpret),
    )
