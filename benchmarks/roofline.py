"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run.

Sources (per EXPERIMENTS.md §Roofline):
- compute term:   per-device HLO FLOPs from the trip-count-corrected HLO walk
                  (utils/hlo_cost.py; XLA's own cost_analysis counts while
                  bodies once — verified and documented) / 197 TFLOP/s bf16.
- collective term: per-device collective payload bytes from the same walk
                  / 50 GB/s ICI link bandwidth.
- memory term:    analytic HBM-traffic model (formulas below) / 819 GB/s.
                  CPU-backend HLO is unfused, so summing per-op bytes would
                  overcount 5-10x vs TPU reality; the analytic model is the
                  honest estimate and is cross-checked against the compiled
                  memory_analysis() residency numbers.

Memory-traffic model (per device, per step):
  train:   3x weight stream (fwd, remat-fwd, bwd: bf16) + optimizer update
           stream (read g,m,v,precond + write w,m: f32) + 2x mask stream
           (read w, write mask+masked in phase 2) + activation checkpoints
           (2x residual stream per layer boundary, bf16)
  prefill: 1x weight stream + KV-cache write + 2x residual per layer
  decode:  1x weight stream + full KV-cache read + O(d_model) vectors
"""
from __future__ import annotations

import json
import os
from typing import Optional

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.models.model import (
    active_param_count,
    frontend_dim,
    layer_plan,
    model_flops_per_token,
    param_count,
)

REPORT = os.path.join(os.path.dirname(__file__), "..", "dryrun_report.json")


def _mesh_dp_tp(multi_pod: bool):
    return (32 if multi_pod else 16), 16


def memory_traffic_bytes(arch: str, shape_name: str, multi_pod: bool) -> float:
    """Analytic per-device HBM traffic for one step (see module docstring)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if multi_pod else 256
    dp, tp = _mesh_dp_tp(multi_pod)
    n = param_count(cfg)
    p_local = n / chips  # FSDP+TP: weights fully sharded
    d = cfg.d_model
    toks_local = shape.seq_len * shape.global_batch / dp
    n_layers = cfg.n_layers

    if shape.kind == "train":
        w_stream = 3 * 2 * p_local  # fwd + remat-fwd + bwd, bf16
        opt_stream = p_local * (4 * 4 + 2 * 4)  # r: g,m,v,P*; w: m,w (f32)
        mask_stream = 2 * 2 * p_local  # read w, write masked (bf16, phase 2)
        act_stream = 2 * 2 * toks_local * d / tp * n_layers  # seq-sharded resid
        return w_stream + opt_stream + mask_stream + act_stream
    if shape.kind == "prefill":
        w_stream = 2 * p_local
        kv = _kv_bytes_per_token(cfg) * toks_local / tp
        act_stream = 2 * 2 * toks_local * d / tp * n_layers
        return w_stream + kv + act_stream
    # decode: weights resident per step (TP-sharded, no FSDP) + cache read
    p_serve = n / tp * 2  # bf16, TP-16 only
    kv_read = _kv_bytes_per_token(cfg) * shape.seq_len * shape.global_batch / chips
    return p_serve + kv_read


def _kv_bytes_per_token(cfg) -> float:
    """Decode-cache bytes per cached token (whole model)."""
    plan = layer_plan(cfg)
    kinds = list(plan.head) + list(plan.period) * plan.n_body + list(plan.tail)
    total = 0.0
    for k in kinds:
        base = k.split(":")[0]
        if base == "attn":
            if cfg.mla is not None:
                total += (cfg.mla.kv_lora + cfg.mla.rope_head_dim) * 2
            else:
                total += 2 * cfg.n_kv * cfg.hd * 2
        elif base == "rec":
            total += 0.0  # O(1) state, not per-token
        elif base == "ssm":
            total += 0.0
    return total


def roofline_row(key: str, rep: dict) -> Optional[dict]:
    if rep.get("status") != "ok":
        return None
    arch, shape_name, mesh = key.split("|")
    multi_pod = mesh == "mp"
    chips = rep["chips"]
    flops_dev = rep["flops"]  # per-device (SPMD module)
    coll_dev = rep["collectives"]["total_bytes"]
    mem_dev = memory_traffic_bytes(arch, shape_name, multi_pod)

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = mem_dev / HBM_BW
    t_coll = coll_dev / ICI_BW_PER_LINK

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" else shape.global_batch
    if shape.kind == "train":
        model_fl = model_flops_per_token(cfg, shape.seq_len) * tokens
    else:
        # inference: 2·N_active (+ attention reads for decode, folded into mem)
        model_fl = 2 * active_param_count(cfg) * tokens
        if shape.kind == "prefill":
            model_fl = model_flops_per_token(cfg, shape.seq_len) / 3 * tokens
    hlo_total = flops_dev * chips
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "key": key,
        "arch": arch,
        "shape": shape_name,
        "mesh": rep["mesh"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_fl,
        "hlo_flops_total": hlo_total,
        "useful_ratio": model_fl / hlo_total if hlo_total else 0.0,
        "roofline_fraction": t_compute / bound if bound else 0.0,
    }


def build_table(report_path: str = REPORT, mesh: str = "sp") -> list[dict]:
    with open(report_path) as f:
        report = json.load(f)
    rows = []
    for key, rep in sorted(report.items()):
        if not key.endswith(f"|{mesh}"):
            continue
        row = roofline_row(key, rep)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)


def run() -> None:
    from benchmarks.common import emit

    rows = build_table()
    for r in rows:
        emit(
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};frac={r['roofline_fraction']:.2f}",
        )
    print()
    print(markdown_table(rows))


if __name__ == "__main__":
    run()
