"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp

import repro.core as core
from repro.data import DataIterator, SyntheticLMDataset, SyntheticTask
from repro.train import Trainer, TrainerConfig

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def append_json(path: str, records: list[dict]) -> None:
    """Append record dicts to a JSON list file (corrupt/missing -> fresh),
    so perf trajectories accumulate across runs (BENCH_*.json)."""
    existing: list[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = []
    with open(path, "w") as f:
        json.dump(existing + records, f, indent=1)


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (jit-compiled fns)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def train_mlp_recipe(
    kind: str,
    *,
    n: int = 2,
    m: int = 4,
    steps: int = 400,
    seed: int = 0,
    lr: float = 3e-3,
    b2: float = 0.99,
    optimizer: str = "step",  # "step" (2-phase adam) | "adam" | "sgd"
    layer_cfg: core.SparsityConfig | None = None,
    switch_at: int | None = None,
    update_v_in_phase2: bool = False,
    t_min_frac: float = 0.1,
    t_max_frac: float = 0.5,
    task: SyntheticTask | None = None,
    **recipe_kw,
) -> dict:
    """Train the teacher-student task with one recipe; return metrics.

    This is the controlled setting used for every paper-figure analogue: the
    teacher is *exactly* n:m-sparse, so dense accuracy is reachable under the
    mask and any gap is an optimization (not capacity) effect — the paper's
    regime.
    """
    task = task or SyntheticTask(seed=seed, n=n, m=m)
    scfg = core.StepConfig(
        learning_rate=lr,
        b2=b2,
        autoswitch=core.AutoSwitchConfig(
            eps=5e-5,
            window=min(100, int(round(1 / (1 - b2)))),
            t_min=int(t_min_frac * steps),
            t_max=int(t_max_frac * steps),
        ),
        switch_at=switch_at,
        update_v_in_phase2=update_v_in_phase2,
    )
    if optimizer == "adam":
        # plain Adam = STEP that never switches
        scfg = core.StepConfig(learning_rate=lr, b2=b2, switch_at=10**9)
    defaults = dict(
        prune_at=int(0.3 * steps),
        dense_until=int(0.2 * steps),
        decay_interval=max(1, int(0.1 * steps)),
    )
    defaults.update(recipe_kw)
    recipe = core.make_recipe(
        kind,
        layer_cfg or core.SparsityConfig(default=core.NMSparsity(n, m)),
        **defaults,
    )

    def loss_fn(p, batch):
        x, y = batch
        l = task.loss(p, x, y)
        return l, {}

    jax.clear_caches()  # long benchmark processes exhaust XLA's dylib space
    data = DataIterator(batch_fn=task.batch, batch_size=64, prefetch=0)
    tr = Trainer(
        loss_fn, recipe, scfg, data,
        TrainerConfig(total_steps=steps, log_every=0, ckpt_every=0),
    )
    t0 = time.perf_counter()
    state, _ = tr.run(task.student_init(jax.random.PRNGKey(seed)), seed=seed)
    wall = time.perf_counter() - t0
    xe, ye = task.batch(10**6, 2048)
    sparse_loss = float(task.loss(recipe.export_sparse(state.params), xe, ye))
    dense_loss = float(task.loss(state.params, xe, ye))
    return {
        "kind": kind,
        "sparse_eval_loss": sparse_loss,
        "dense_eval_loss": dense_loss,
        "phase2": bool(getattr(state.opt, "phase2", False)),
        "t0": int(getattr(state.opt, "t0", 0)),
        "wall_s": wall,
        "us_per_step": wall / steps * 1e6,
        "state": state,
        "recipe": recipe,
        "task": task,
    }


def train_lm_recipe(
    kind: str,
    *,
    n: int = 2,
    m: int = 4,
    steps: int = 120,
    seed: int = 0,
    layer_cfg: core.SparsityConfig | None = None,
    switch_at: int | None = None,
    update_v_in_phase2: bool = False,
    **recipe_kw,
) -> dict:
    """GPT-2-family LM on the synthetic Markov corpus — the paper's actual
    regime (attention model + Adam + noisy gradients), used for the
    aggressive-ratio sweep, layer-wise table, and phase ablations."""
    from repro.configs import get_config
    from repro.models.model import TransformerLM

    cfg = get_config("gpt2-paper", smoke=True)
    model = TransformerLM(cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, seed=42, n_states=16)

    def loss_fn(p, batch):
        return model.loss(p, batch, chunk=16)

    defaults = dict(
        prune_at=int(0.3 * steps),
        dense_until=int(0.2 * steps),
        decay_interval=max(1, int(0.1 * steps)),
    )
    defaults.update(recipe_kw)
    recipe = core.make_recipe(
        kind, layer_cfg or core.SparsityConfig(default=core.NMSparsity(n, m)),
        **defaults,
    )
    scfg = core.StepConfig(
        learning_rate=3e-3,
        b2=0.98,
        autoswitch=core.AutoSwitchConfig(
            eps=2e-5, window=25, t_min=int(0.15 * steps), t_max=int(0.5 * steps)
        ),
        switch_at=switch_at,
        update_v_in_phase2=update_v_in_phase2,
    )
    import jax as _jax

    _jax.clear_caches()  # long benchmark processes exhaust XLA's dylib space
    data = DataIterator(batch_fn=ds.batch, batch_size=8, prefetch=0)
    tr = Trainer(loss_fn, recipe, scfg, data,
                 TrainerConfig(total_steps=steps, log_every=0, ckpt_every=0))

    t0 = time.perf_counter()
    state, _ = tr.run(model.init(_jax.random.PRNGKey(seed)), seed=seed)
    wall = time.perf_counter() - t0
    eval_batch = ds.batch(99_999, 16)
    loss, _ = model.loss(recipe.export_sparse(state.params), eval_batch, chunk=16)
    return {
        "kind": kind,
        "sparse_eval_loss": float(loss),
        "phase2": bool(getattr(state.opt, "phase2", False)),
        "t0": int(getattr(state.opt, "t0", 0)),
        "us_per_step": wall / steps * 1e6,
        "state": state,
        "recipe": recipe,
    }
