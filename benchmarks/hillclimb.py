"""§Perf hillclimb harness: lower a cell under a named variant, re-analyze.

Each variant is a dict of knobs consumed by the dryrun lowering functions:
  fsdp        — ZeRO-3 weight sharding on the data axis (vs replicated+TP)
  seq_shard   — sequence-parallel residual stream
  ep_shard    — EP sharding constraint on the MoE dispatch buffer
  remat       — activation checkpointing of the scan body
  serve_compressed — model the N:M-compressed weight stream (decode memory
                     term; numerics unchanged, accounting analytic)

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell starcoder2-3b:train_4k \
      --variant baseline --variant no_fsdp ...
Results append to perf_log.json for EXPERIMENTS.md §Perf.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as core
from repro.configs import get_config, SHAPES
from repro.launch import dryrun as D
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import model as M
from repro.utils import hlo_cost as HC
from repro.utils import hlo_analysis as H

PERF_LOG = os.path.join(os.path.dirname(__file__), "..", "perf_log.json")


def _ep_constraint(mesh):
    from repro.distributed.sharding import _dp

    dp = _dp(mesh)

    def fn(x):
        if x.ndim == 2:  # (T, d) tokens: dp-sharded, replicated over model
            spec = P(dp, None)
        else:  # (E, C, d) buffers: experts over model (EP)
            spec = P("model", *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return fn


def lower_variant(arch: str, shape_name: str, mesh, knobs: dict):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return _lower_train_variant(cfg, shape, mesh, knobs)
    if shape.kind == "decode":
        return D.lower_decode(cfg, shape, mesh, fsdp=knobs.get("fsdp", False),
                              kv_shard=knobs.get("kv_shard", "seq"))
    return D.lower_prefill(cfg, shape, mesh, seq_shard=knobs.get("seq_shard", True),
                           fsdp=knobs.get("fsdp", True))


def _lower_train_variant(cfg, shape, mesh, knobs):
    from repro.core.step_optimizer import StepConfig, step_optimizer
    from repro.train.loop import make_train_step
    from repro.distributed.sharding import (
        batch_pspecs, shardings_for, state_pspecs,
    )

    recipe = D.make_recipe(cfg, *knobs.get("nm", (2, 4)))
    step_cfg = StepConfig(learning_rate=1e-4)
    opt = step_optimizer(step_cfg)
    bc = (
        D._block_constraint(mesh, seq_axis=knobs.get("seq_shard", True))
        if knobs.get("block_constraint", True)
        else None
    )
    ep = _ep_constraint(mesh) if knobs.get("ep_shard", False) else None
    lc = None
    if knobs.get("shard_logits", False):
        from repro.distributed.sharding import _dp

        dpax = _dp(mesh)

        def lc(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dpax, None, "model"))
            )

    def loss(p, batch):
        return M.loss_fn(
            p, cfg, batch,
            remat=knobs.get("remat", True),
            block_constraint=bc,
            ep_constraint=ep,
            logits_constraint=lc,
        )

    step = make_train_step(loss, recipe, opt, grad_clip=1.0)
    state_abs = D.abstract_train_state(cfg, recipe, step_cfg)
    specs = D.input_specs(cfg, shape)
    state_sh = shardings_for(
        mesh, state_abs, state_pspecs(mesh, state_abs, fsdp=knobs.get("fsdp", True))
    )
    batch_sh = shardings_for(mesh, specs["batch"], batch_pspecs(mesh, specs["batch"]))
    fn = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=0)
    return fn.lower(state_abs, specs["batch"])


VARIANTS = {
    "baseline": {},
    "gather_moe": {},          # moe gather-only dispatch (code change, rerun)
    "shard_logits": {"shard_logits": True},
    "shard_logits_no_fsdp": {"shard_logits": True, "fsdp": False},
    "kv_seq_shard": {"kv_shard": "seq"},
    "kv_feature_shard": {"kv_shard": "feature"},
    "gather_moe_ep": {"ep_shard": True},
    "no_constraint": {"block_constraint": False},
    "no_constraint_no_fsdp": {"block_constraint": False, "fsdp": False},
    "no_fsdp": {"fsdp": False},
    "no_seq_shard": {"seq_shard": False},
    "no_remat": {"remat": False},
    "ep_shard": {"ep_shard": True},
    "ep_shard_no_fsdp": {"ep_shard": True, "fsdp": False},
    "no_fsdp_no_seq": {"fsdp": False, "seq_shard": False},
    "decode_fsdp": {"fsdp": True},  # decode: FSDP'd weights (gather per step)
}


def run_variant(arch: str, shape_name: str, variant: str, multi_pod=False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    knobs = VARIANTS[variant]
    t0 = time.time()
    with mesh:
        lowered = lower_variant(arch, shape_name, mesh, knobs)
        compiled = lowered.compile()
    text = compiled.as_text()
    walk = HC.analyze(text)
    mem = H.memory_analysis_dict(compiled)
    per_dev_resident = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
    )
    out = {
        "cell": f"{arch}|{shape_name}",
        "variant": variant,
        "knobs": knobs,
        "compile_s": round(time.time() - t0, 1),
        "flops_dev": walk["flops"],
        "collective_bytes_dev": walk["collective_total"],
        "collective_per_kind": walk["collective_bytes"],
        "resident_bytes_dev": per_dev_resident,
        "compute_term_s": walk["flops"] / PEAK_FLOPS_BF16,
        "collective_term_s": walk["collective_total"] / ICI_BW_PER_LINK,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", default=None)
    ap.add_argument("--note", default="")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    variants = args.variant or ["baseline"]
    log = []
    if os.path.exists(PERF_LOG):
        log = json.load(open(PERF_LOG))
    for v in variants:
        print(f"[hillclimb] {args.cell} variant={v} ...", flush=True)
        try:
            rep = run_variant(arch, shape, v)
            rep["note"] = args.note
            print(
                f"  flops/dev={rep['flops_dev']:.3e} "
                f"coll/dev={rep['collective_bytes_dev']/1e9:.2f}GB "
                f"resident={rep['resident_bytes_dev']/1e9:.2f}GB "
                f"compute_t={rep['compute_term_s']:.3f}s "
                f"coll_t={rep['collective_term_s']:.3f}s",
                flush=True,
            )
        except Exception as e:
            rep = {"cell": args.cell, "variant": v, "error": f"{type(e).__name__}: {e}"}
            print(f"  ERROR {rep['error'][:200]}", flush=True)
        log.append(rep)
        json.dump(log, open(PERF_LOG, "w"), indent=1)


if __name__ == "__main__":
    main()
