"""Paper Tables 2 & 3 analogue: recipe comparison under Adam.

Dense vs ASP vs SR-STE vs STEP at 2:4 on (a) the controlled teacher-student
task and (b) the GPT-2-family LM on the synthetic corpus. The paper's claim
to reproduce: with Adam, STEP's sparse eval quality ~ dense, while ASP and
SR-STE show a visible drop.
"""
from __future__ import annotations

import time

import jax

import repro.core as core
from benchmarks.common import emit, train_mlp_recipe
from repro.configs import get_config
from repro.data import DataIterator, SyntheticLMDataset
from repro.models.model import TransformerLM
from repro.train import Trainer, TrainerConfig

RECIPES = ["dense", "asp", "sr_ste", "step"]


def table_mlp(seeds=(0, 1, 2), steps=400) -> dict:
    out = {}
    for kind in RECIPES:
        losses = []
        t0s = []
        us = 0.0
        for s in seeds:
            r = train_mlp_recipe(kind, steps=steps, seed=s)
            losses.append(r["sparse_eval_loss"])
            t0s.append(r["t0"])
            us = r["us_per_step"]
        med = sorted(losses)[len(losses) // 2]
        out[kind] = med
        emit(
            f"recipes_mlp/{kind}",
            us,
            f"sparse_eval_loss={med:.4f};t0={t0s[len(t0s)//2]}",
        )
    return out


def table_lm(steps=160) -> dict:
    cfg = get_config("gpt2-paper", smoke=True)
    model = TransformerLM(cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, seed=42, n_states=16)

    def loss_fn(p, batch):
        return model.loss(p, batch, chunk=16)

    out = {}
    for kind in RECIPES:
        jax.clear_caches()
        recipe = core.make_recipe(
            kind,
            core.SparsityConfig(default=core.NMSparsity(2, 4)),
            prune_at=int(0.3 * steps),
            dense_until=int(0.2 * steps),
        )
        scfg = core.StepConfig(
            learning_rate=3e-3,
            b2=0.98,
            autoswitch=core.AutoSwitchConfig(
                eps=2e-5, window=25, t_min=int(0.15 * steps), t_max=int(0.5 * steps)
            ),
        )
        data = DataIterator(batch_fn=ds.batch, batch_size=8, prefetch=0)
        tr = Trainer(loss_fn, recipe, scfg, data,
                     TrainerConfig(total_steps=steps, log_every=0, ckpt_every=0))
        t0 = time.perf_counter()
        state, _ = tr.run(model.init(jax.random.PRNGKey(0)))
        wall = time.perf_counter() - t0
        eval_batch = ds.batch(99_999, 16)
        loss, _ = model.loss(recipe.export_sparse(state.params), eval_batch, chunk=16)
        out[kind] = float(loss)
        emit(
            f"recipes_lm/{kind}",
            wall / steps * 1e6,
            f"sparse_eval_loss={float(loss):.4f};phase2={bool(getattr(state.opt,'phase2',0))}",
        )
    return out


def run() -> None:
    table_mlp()
    table_lm()


if __name__ == "__main__":
    run()
