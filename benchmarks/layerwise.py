"""Paper Table 4: layer-wise mixed N:M (DominoSearch) with and without STEP.

Per-layer N (shared M=8) assigned by the greedy-energy DominoSearch
approximation to meet a global density budget; "DS" trains it with plain
STE×Adam, "DS+STEP" adds the precondition phase. LM task (paper regime).
"""
from __future__ import annotations

import jax

import repro.core as core
from benchmarks.common import emit, train_lm_recipe
from repro.configs import get_config
from repro.models.model import TransformerLM


def run(steps=120) -> dict:
    out = {}
    cfg = get_config("gpt2-paper", smoke=True)
    params0 = TransformerLM(cfg).init(jax.random.PRNGKey(0))
    base = core.SparsityConfig(default=core.NMSparsity(2, 8))
    for density in (0.5, 0.25):
        domino_cfg = core.domino_search(params0, base, m=8, target_density=density)
        for label, kind in (("ds", "ste"), ("ds_step", "step")):
            r = train_lm_recipe(kind, steps=steps, seed=0, layer_cfg=domino_cfg)
            out[(label, density)] = r["sparse_eval_loss"]
            emit(
                f"layerwise/{label}/density_{density}",
                r["us_per_step"],
                f"sparse_eval_loss={r['sparse_eval_loss']:.4f}",
            )
    return out


if __name__ == "__main__":
    run()
