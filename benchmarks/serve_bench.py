"""Serving-engine benchmark: dense vs compressed, slab vs paged KV cache,
steps-per-dispatch (fused decode) sweep.

Three sweeps through ``repro.serving.DecodeEngine``:

1. **dense vs compressed** (slab layout, homogeneous prompts): the same
   request load served on the masked-dense tree and on the N:M-compressed
   tree (the ``nm_spmm`` dispatch path), reporting µs/decode-step plus
   tokens/s and the HBM weight-bytes ratio.  On CPU dispatch selects the
   vectorized XLA path (``kernels.nm_spmm.nm_spmm_xla``): at smoke sizes
   compressed decode matches-or-beats dense at batch 1 and stays within
   2x above (was 8x slower on the seed's scatter-decompress route); the
   HBM ratio column is the quantity the TPU Pallas kernel converts into
   decode-step time.

Each record also carries the decode-step roofline inputs
(``weight_bytes_per_step`` / ``kv_bytes_per_step`` /
``bytes_read_per_step``): what one step must stream from HBM, with
compressed leaves at stored size and only *live* KV tokens counted (the
paged fast path's read set).

2. **slab vs paged** (compressed tree, heterogeneous prompt lengths): the
   slab engine allocates ``max_batch × max_len`` token slots per layer no
   matter the request mix; the paged engine is given the *same HBM cache
   budget* (``num_pages × page_size == max_batch × max_len``) but hands
   pages out block-granularly, so short requests stop reserving worst-case
   slabs and more requests decode concurrently.  Reported per engine:
   admitted concurrency, KV-cache bytes, cache token-utilization,
   preemptions, tokens/s.

3. **steps-per-dispatch** (compressed, paged, greedy): the same request
   load at K ∈ {1, 4, 8} fused decode steps per dispatch, with buffer
   donation on (and a K=1 ``donate=False`` baseline).  Each record splits
   per-token wall time into the device dispatch (``us_per_decode_step``)
   and the host-scheduling overhead amortized over the K tokens one sync
   buys (``us_per_decode_step_host`` / ``host_overhead_frac``), plus
   ``host_syncs`` and the incremental page-table sync counters.  Greedy
   streams are asserted bit-identical to the K=1 undonated baseline
   (``greedy_parity_with_k1``).

4. **sharded serving** (subprocess, forced-8-host-device CPU mesh): the
   same compressed paged load served by ``launch/serve.py`` on a ``1,1``
   and a ``2,4`` ``(data, model)`` mesh.  Each record carries the
   per-shard weight / cache HBM bytes (what one device must hold — the
   quantity TP exists to shrink) and the decode executable's collective
   mix (counts + bytes by kind), so the sharding overhead is measurable
   next to the single-device rows.

Every row is also appended to a machine-readable ``BENCH_serve.json``
(list of record dicts) so the perf trajectory accumulates across runs.
**Schema note**: every record carries a ``mesh`` field —
``{"shape": [...], "axes": [...]}`` of the serving mesh, with
``{"shape": [1], "axes": []}`` meaning a single-device engine — so
sharded and single-device sweeps stay comparable; a one-time
``sweep == "schema"`` record in the JSON documents this.

    PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

import repro.core as core
from benchmarks.common import append_json, emit
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params, compression_report

OUT_JSON = "BENCH_serve.json"

# every record's ``mesh`` field: single-device engines record this so rows
# sort/filter uniformly against sharded sweeps
MESH_SINGLE = {"shape": [1], "axes": []}

SCHEMA_NOTE = {
    "suite": "serve",
    "sweep": "schema",
    "note": (
        "records appended from the mesh-native serving PR onward carry "
        "mesh={shape:[...],axes:[...]} (the serving mesh; "
        "{shape:[1],axes:[]} = single-device; earlier rows predate the "
        "field and were all single-device). sharded_serving rows add "
        "*_per_shard HBM bytes and decode_collective_* fields from the "
        "compiled decode executable; from the per-shard kernel PR onward "
        "they also carry kernel_route (xla | shard_map), per-shard "
        "roofline bytes (*_per_step_per_shard), and "
        "greedy_parity_across_routes on the (2,4) rows."
    ),
}


def _serving_trees(arch: str, nm):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n, m = nm
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)
    comp = compress_params(sparse, recipe.sparsity)
    ratio = compression_report(sparse, comp)["ratio"]
    return cfg, model, sparse, comp, ratio


def _drain_streams(engine, prompts, gen: int) -> tuple[dict, list[list[int]]]:
    """Submit every prompt, drain the engine; returns (stats, per-request
    token streams in submit order — the K-sweep parity check)."""
    sp = SamplingParams(max_new_tokens=gen)
    uids = [engine.submit(p, sp) for p in prompts]
    res = engine.run()
    return engine.stats(), [res[u].tokens for u in uids]


def _drain(engine, prompts, gen: int) -> dict:
    return _drain_streams(engine, prompts, gen)[0]


def _hetero_prompts(cfg, n_requests: int, max_prompt: int) -> list[list[int]]:
    """Short-heavy heterogeneous mix: the regime where slabs waste HBM."""
    out = []
    for r in range(n_requests):
        plen = 4 + (r * 7) % max(1, max_prompt - 4)  # 4 .. max_prompt-1
        toks = jax.random.randint(
            jax.random.PRNGKey(500 + r), (plen,), 0, cfg.vocab
        )
        out.append([int(t) for t in toks])
    return out


def _sharded_sweep(
    arch: str, nm, prompt_len: int, gen: int
) -> tuple[list[dict], list[str]]:
    """Sweep 4: serve the compressed paged load tensor-parallel on an
    emulated 8-device CPU mesh, via a ``launch/serve.py`` subprocess (the
    ``--xla_force_host_platform_device_count`` flag must precede jax init,
    which this process has long passed).

    The (2,4) mesh runs twice: once on the default kernel route (the
    GSPMD-partitioned XLA gathered path on CPU) and once with
    ``REPRO_KERNEL_MODE=shard_map`` forcing the per-shard wrapper
    (``kernels.sharded``), so BENCH_serve.json captures the xla-vs-
    shard_map route comparison with per-shard roofline bytes.  Returns
    ``(records, route_parity_failures)`` — the caller asserts the greedy
    streams of the two routes match *after* persisting the records."""
    n, m = nm
    records: list[dict] = []
    failures: list[str] = []
    streams: dict[tuple[str, str], list] = {}
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    for mesh_arg, forced in (("1,1", None), ("2,4", None), ("2,4", "shard_map")):
        run_env = dict(env)
        run_env.pop("REPRO_KERNEL_MODE", None)
        if forced:
            run_env["REPRO_KERNEL_MODE"] = forced
        label = mesh_arg + (f"/{forced}" if forced else "")
        cmd = [
            sys.executable, "-m", "repro.launch.serve", "--arch", arch,
            "--nm", f"{n}:{m}", "--batch", "2",
            "--prompt-len", str(prompt_len), "--gen", str(gen),
            # 16 pages: divisible by the 4-way model axis, so the pool's
            # pages axis actually shards (sanitize_spec would otherwise
            # degrade an odd page count to a replicated pool)
            "--paged", "--page-size", "4", "--num-pages", "16",
            "--mesh", mesh_arg,
        ]
        try:
            out = subprocess.run(
                cmd, capture_output=True, text=True, env=run_env, timeout=1200
            )
        except subprocess.TimeoutExpired:
            emit(f"serve/{arch}/{n}:{m}/sharded/{label}", 0.0, "TIMEOUT")
            continue
        summary = None
        for line in out.stdout.splitlines():
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            if "summary" in d:
                summary = d["summary"]
        if summary is None:
            emit(
                f"serve/{arch}/{n}:{m}/sharded/{label}", 0.0,
                f"FAILED rc={out.returncode}: {out.stderr[-200:]}",
            )
            continue
        route = summary.get("kernel_route", "?")
        streams[(mesh_arg, route)] = summary.get("greedy_streams")
        emit(
            f"serve/{arch}/{n}:{m}/sharded/{label}",
            summary["ms_per_decode_step"] * 1e3,
            f"route={route} "
            f"w_bytes/shard={summary['weight_bytes_per_shard']} "
            f"coll_bytes={summary['decode_collective_total']:.0f} "
            f"repl_leaves={summary['replicated_weight_leaves']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "sharded_serving",
                "mesh": summary["mesh"],
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": summary["layout"],
                "batch": 2,
                "kernel_route": route,
                "us_per_decode_step": summary["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host":
                    summary["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": summary["host_overhead_frac"],
                "tokens_per_s": summary["tokens_per_s"],
                "decode_steps": summary["decode_steps"],
                "weight_bytes_per_shard": summary["weight_bytes_per_shard"],
                "cache_bytes_per_shard": summary["cache_bytes_per_shard"],
                "decode_collective_bytes": summary["decode_collective_bytes"],
                "decode_collective_total": summary["decode_collective_total"],
                "replicated_weight_leaves":
                    summary["replicated_weight_leaves"],
                # per-shard decode roofline (weight slice + split KV read)
                "model_shards": summary.get("model_shards"),
                "weight_bytes_per_step_per_shard":
                    summary.get("weight_bytes_per_step_per_shard"),
                "kv_bytes_per_step_per_shard":
                    summary.get("kv_bytes_per_step_per_shard"),
                "bytes_read_per_step_per_shard":
                    summary.get("bytes_read_per_step_per_shard"),
            }
        )
    # greedy-stream parity between the two (2,4) kernel routes: same mesh,
    # same seeds — the streams must be token-identical
    got = {r: s for (mesh_arg, r), s in streams.items() if mesh_arg == "2,4"}
    if len(got) == 2:
        a, b = got.values()
        if a is None or b is None or a != b:
            failures.append(f"2,4 routes {sorted(got)} streams differ")
        for rec in records:
            if rec["mesh"] and rec["mesh"].get("shape") == [2, 4]:
                rec["greedy_parity_across_routes"] = a is not None and a == b
    elif streams:  # one of the (2,4) runs failed outright
        failures.append(f"expected 2 routes on the 2,4 mesh, got {sorted(got)}")
    return records, failures


def run(
    arch: str = "gpt2-paper",
    nm=(2, 4),
    batches=(1, 2, 4),
    prompt_len: int = 8,
    gen: int = 16,
    steps_sweep=(1, 4, 8),
    out_json: str = OUT_JSON,
) -> list[dict]:
    cfg, model, sparse, comp, ratio = _serving_trees(arch, nm)
    n, m = nm
    records: list[dict] = []

    # -- sweep 1: dense vs compressed (slab), homogeneous batch ----------------
    for batch in batches:
        for mode, tree in (("dense", sparse), ("compressed", comp)):
            engine = DecodeEngine(
                model, tree, max_batch=batch, max_len=prompt_len + gen + 1
            )
            prompts = [
                [
                    int(t)
                    for t in jax.random.randint(
                        jax.random.PRNGKey(100 + r), (prompt_len,), 0, cfg.vocab
                    )
                ]
                for r in range(2 * batch)  # 2x oversubscribed: slot reuse on
            ]
            st = _drain(engine, prompts, gen)
            emit(
                f"serve/{arch}/{n}:{m}/{mode}/b{batch}",
                st["ms_per_decode_step"] * 1e3,
                f"tok/s={st['tokens_per_s']:.1f} "
                f"steps={st['decode_steps']} hbm_ratio={ratio:.3f}",
            )
            records.append(
                {
                    "suite": "serve",
                    "sweep": "dense_vs_compressed",
                    "mesh": MESH_SINGLE,
                    "arch": arch,
                    "nm": f"{n}:{m}",
                    "mode": mode,
                    "layout": "slab",
                    "batch": batch,
                    "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                    "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                    "host_overhead_frac": st["host_overhead_frac"],
                    "tokens_per_s": st["tokens_per_s"],
                    "decode_steps": st["decode_steps"],
                    "hbm_weight_ratio": ratio,
                    "kv_cache_bytes": st["kv_cache_bytes"],
                    # roofline inputs: what one decode step must read
                    "weight_bytes_per_step": st["weight_bytes_per_step"],
                    "kv_bytes_per_step": st["kv_bytes_per_step"],
                    "bytes_read_per_step": st["bytes_read_per_step"],
                }
            )

    # -- sweep 2: slab vs paged at equal HBM cache budget ----------------------
    slab_batch, page_size = 2, 8
    max_len = prompt_len + gen + 9  # headroom for the longest hetero prompt
    budget_tokens = slab_batch * max_len
    num_pages = budget_tokens // page_size
    prompts = _hetero_prompts(cfg, 6 * slab_batch, max_prompt=prompt_len + 8)
    for layout, kwargs in (
        ("slab", {"max_batch": slab_batch}),
        (
            "paged",
            {
                "max_batch": 4 * slab_batch,
                "num_pages": num_pages,
                "page_size": page_size,
            },
        ),
    ):
        engine = DecodeEngine(model, comp, max_len=max_len, **kwargs)
        st = _drain(engine, prompts, gen)
        util = st["hbm_cache_utilization"]
        emit(
            f"serve/{arch}/{n}:{m}/paged_sweep/{layout}",
            st["ms_per_decode_step"] * 1e3,
            f"tok/s={st['tokens_per_s']:.1f} "
            f"concurrency={st['max_concurrency']} util={util:.2f} "
            f"kv_bytes={st['kv_cache_bytes']} preempt={st['preemptions']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "slab_vs_paged",
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": layout,
                "batch": kwargs["max_batch"],
                "budget_tokens": budget_tokens,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": st["host_overhead_frac"],
                "tokens_per_s": st["tokens_per_s"],
                "decode_steps": st["decode_steps"],
                "max_concurrency": st["max_concurrency"],
                "preemptions": st["preemptions"],
                "hbm_weight_ratio": ratio,
                "kv_cache_bytes": st["kv_cache_bytes"],
                "hbm_cache_utilization": util,
                "weight_bytes_per_step": st["weight_bytes_per_step"],
                "kv_bytes_per_step": st["kv_bytes_per_step"],
                "bytes_read_per_step": st["bytes_read_per_step"],
            }
        )

    paged_rec = next(r for r in records if r.get("layout") == "paged")
    slab_rec = next(
        r for r in records if r.get("sweep") == "slab_vs_paged"
        and r["layout"] == "slab"
    )
    emit(
        f"serve/{arch}/{n}:{m}/paged_sweep/concurrency_gain",
        0.0,
        f"paged={paged_rec['max_concurrency']} slab={slab_rec['max_concurrency']}",
    )

    # -- sweep 3: steps-per-dispatch (fused K-step decode, donated caches) -----
    k_batch, k_page_size = 2, 8
    k_max_len = prompt_len + gen + 1
    k_pages = 2 * k_batch * (-(-k_max_len // k_page_size))
    k_prompts = [
        [
            int(t)
            for t in jax.random.randint(
                jax.random.PRNGKey(900 + r), (prompt_len,), 0, cfg.vocab
            )
        ]
        for r in range(2 * k_batch)
    ]
    _, base_streams = _drain_streams(
        DecodeEngine(
            model, comp, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size, donate=False,
        ),
        k_prompts, gen,
    )
    parity_failures: list[int] = []
    for k in steps_sweep:
        engine = DecodeEngine(
            model, comp, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size,
            steps_per_dispatch=k, donate=True,
        )
        st, streams = _drain_streams(engine, k_prompts, gen)
        parity = streams == base_streams
        if not parity:
            parity_failures.append(k)
        emit(
            f"serve/{arch}/{n}:{m}/steps_per_dispatch/k{k}",
            st["ms_per_decode_step"] * 1e3,
            f"host_us/tok={st['ms_per_decode_step_host'] * 1e3:.1f} "
            f"host_frac={st['host_overhead_frac']:.3f} "
            f"syncs={st['host_syncs']} parity={parity}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "steps_per_dispatch",
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": "paged",
                "batch": k_batch,
                "steps_per_dispatch": k,
                "donate": True,
                "greedy_parity_with_k1": parity,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": st["host_overhead_frac"],
                "host_syncs": st["host_syncs"],
                "decode_steps": st["decode_steps"],
                "tokens_per_s": st["tokens_per_s"],
                "table_full_uploads": st["table_full_uploads"],
                "table_row_syncs": st["table_row_syncs"],
                "table_syncs": st["table_syncs"],
            }
        )

    # -- sweep 4: sharded serving on an emulated 8-device CPU mesh -------------
    sharded_records, route_failures = _sharded_sweep(arch, nm, prompt_len, gen)
    records.extend(sharded_records)

    if out_json:
        # one-time schema note: documents the mesh field + per-shard columns
        have_note = False
        if os.path.exists(out_json):
            try:
                with open(out_json) as f:
                    have_note = any(
                        r.get("sweep") == "schema" for r in json.load(f)
                    )
            except (json.JSONDecodeError, OSError):
                pass
        append_json(
            out_json, records if have_note else [SCHEMA_NOTE] + records
        )
    # fail *after* persisting: a parity break must not discard the run's
    # records (the greedy_parity_with_k1 / greedy_parity_across_routes
    # fields mark the offending rows)
    assert not parity_failures, (
        f"fused decode diverged from the K=1 baseline at K={parity_failures}"
    )
    assert not route_failures, (
        f"xla vs shard_map kernel routes diverged: {route_failures}"
    )
    return records
