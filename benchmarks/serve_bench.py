"""Serving-engine benchmark: dense vs compressed, slab vs paged KV cache,
steps-per-dispatch (fused decode) sweep.

Three sweeps through ``repro.serving.DecodeEngine``:

1. **dense vs compressed** (slab layout, homogeneous prompts): the same
   request load served on the masked-dense tree and on the N:M-compressed
   tree (the ``nm_spmm`` dispatch path), reporting µs/decode-step plus
   tokens/s and the HBM weight-bytes ratio.  On CPU dispatch selects the
   vectorized XLA path (``kernels.nm_spmm.nm_spmm_xla``): at smoke sizes
   compressed decode matches-or-beats dense at batch 1 and stays within
   2x above (was 8x slower on the seed's scatter-decompress route); the
   HBM ratio column is the quantity the TPU Pallas kernel converts into
   decode-step time.

Each record also carries the decode-step roofline inputs
(``weight_bytes_per_step`` / ``kv_bytes_per_step`` /
``bytes_read_per_step``): what one step must stream from HBM, with
compressed leaves at stored size and only *live* KV tokens counted (the
paged fast path's read set).

2. **slab vs paged** (compressed tree, heterogeneous prompt lengths): the
   slab engine allocates ``max_batch × max_len`` token slots per layer no
   matter the request mix; the paged engine is given the *same HBM cache
   budget* (``num_pages × page_size == max_batch × max_len``) but hands
   pages out block-granularly, so short requests stop reserving worst-case
   slabs and more requests decode concurrently.  Reported per engine:
   admitted concurrency, KV-cache bytes, cache token-utilization,
   preemptions, tokens/s.

3. **steps-per-dispatch** (compressed, paged, greedy): the same request
   load at K ∈ {1, 4, 8} fused decode steps per dispatch, with buffer
   donation on (and a K=1 ``donate=False`` baseline).  Each record splits
   per-token wall time into the device dispatch (``us_per_decode_step``)
   and the host-scheduling overhead amortized over the K tokens one sync
   buys (``us_per_decode_step_host`` / ``host_overhead_frac``), plus
   ``host_syncs`` and the incremental page-table sync counters.  Greedy
   streams are asserted bit-identical to the K=1 undonated baseline
   (``greedy_parity_with_k1``).

4. **sharded serving** (subprocess, forced-8-host-device CPU mesh): the
   same compressed paged load served by ``launch/serve.py`` on a ``1,1``
   and a ``2,4`` ``(data, model)`` mesh.  Each record carries the
   per-shard weight / cache HBM bytes (what one device must hold — the
   quantity TP exists to shrink) and the decode executable's collective
   mix (counts + bytes by kind), so the sharding overhead is measurable
   next to the single-device rows.

5. **prefix caching + int8 KV pages** (compressed, paged): (a) requests
   sharing a long prompt head served with ``prefix_cache=True`` — TTFT of
   a radix-index *hit* (only the uncached tail prefills) vs a *cold*
   admission of the same prompt, with the hit rate recorded next to the
   ratio; (b) the same oversubscribed request load on a default-dtype
   pool vs an int8 pool given the **same KV HBM byte budget** (more pages
   at equal bytes) — admitted concurrency is the column int8 exists to
   grow.

6. **self-speculative decoding** (paged, greedy): the sweep-3 workload
   served with ``spec_gamma = max(K)`` — a drafter scan plus one chunked
   verify pass per host sync.  The ``self`` pairing (drafter == verifier,
   acceptance 1.0 by construction) is asserted to commit strictly more
   tokens per host sync than the best fused K=8 dispatch; the ``cross``
   pairing (compressed drafter, masked-dense verifier) records the honest
   acceptance rate and amortized bytes/accepted-token.  Both pairings'
   greedy streams must be bit-identical to a plain engine serving the
   verifier tree (losslessness).

Every row is also appended to a machine-readable ``BENCH_serve.json``
(list of record dicts) so the perf trajectory accumulates across runs.
**Schema note**: every record carries a ``mesh`` field —
``{"shape": [...], "axes": [...]}`` of the serving mesh, with
``{"shape": [1], "axes": []}`` meaning a single-device engine — so
sharded and single-device sweeps stay comparable; a one-time
``sweep == "schema"`` record in the JSON documents this (upserted in
place when its text changes — never duplicated, never stale).

    PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

import repro.core as core
from benchmarks.common import append_json, emit
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params, compression_report

OUT_JSON = "BENCH_serve.json"

# every record's ``mesh`` field: single-device engines record this so rows
# sort/filter uniformly against sharded sweeps
MESH_SINGLE = {"shape": [1], "axes": []}

SCHEMA_NOTE = {
    "suite": "serve",
    "sweep": "schema",
    "note": (
        "records appended from the mesh-native serving PR onward carry "
        "mesh={shape:[...],axes:[...]} (the serving mesh; "
        "{shape:[1],axes:[]} = single-device; earlier rows predate the "
        "field and were all single-device). sharded_serving rows add "
        "*_per_shard HBM bytes and decode_collective_* fields from the "
        "compiled decode executable; from the per-shard kernel PR onward "
        "they also carry kernel_route (xla | shard_map), per-shard "
        "roofline bytes (*_per_step_per_shard), and "
        "greedy_parity_across_routes on the (2,4) rows. from the "
        "prefix-cache PR onward, prefix_cache rows carry ttft_cold_ms / "
        "ttft_hit_ms / prefix_hit_rate, and kv_int8 rows compare admitted "
        "concurrency on a default-dtype vs int8 pool at the same KV HBM "
        "byte budget (kv_cache_bytes / num_pages per variant). from the "
        "device-scheduler PR onward, device_scheduler rows record the "
        "run-until-stop while-loop engine (variant device | device_async): "
        "host_syncs counts full-drain cycle boundaries (not dispatches), "
        "host_syncs_per_token amortizes them over decode tokens, "
        "us_per_decode_step_host_fixedk carries the best fixed-K sweep-3 "
        "baseline for comparison, refills counts on-device lane swaps from "
        "the staged ring, and itl_ms_p50/p99 are host-side inter-token "
        "latencies. from the speculative-decoding PR onward, speculative "
        "rows record a drafter/verifier pairing (variant self | cross): "
        "host_syncs counts draft+verify round trips, "
        "host_syncs_per_accepted_token amortizes them over committed "
        "tokens next to the K=8 fused baseline "
        "(host_syncs_per_token_fixedk), acceptance_rate / "
        "accepted_per_verify / bytes_per_accepted_token carry the "
        "speculative economics, and greedy_parity_with_verifier marks "
        "losslessness against a plain engine serving the verifier tree."
    ),
}


def _upsert_schema_note(path: str) -> None:
    """Keep exactly one ``sweep == "schema"`` record, current text.

    Append-only handling left a stale note behind whenever the schema
    grew: this rewrites the note *in place* when its text changed, drops
    accidental duplicates, and prepends it when missing — idempotent, so
    every bench run can call it unconditionally."""
    existing: list[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = []
    notes = [r for r in existing if r.get("sweep") == "schema"]
    if len(notes) == 1 and notes[0].get("note") == SCHEMA_NOTE["note"]:
        return
    rest = [r for r in existing if r.get("sweep") != "schema"]
    with open(path, "w") as f:
        json.dump([SCHEMA_NOTE] + rest, f, indent=1)


def _serving_trees(arch: str, nm):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n, m = nm
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)
    comp = compress_params(sparse, recipe.sparsity)
    ratio = compression_report(sparse, comp)["ratio"]
    return cfg, model, sparse, comp, ratio


def _drain_streams(engine, prompts, gen: int) -> tuple[dict, list[list[int]]]:
    """Submit every prompt, drain the engine; returns (stats, per-request
    token streams in submit order — the K-sweep parity check)."""
    sp = SamplingParams(max_new_tokens=gen)
    uids = [engine.submit(p, sp) for p in prompts]
    res = engine.run()
    return engine.stats(), [res[u].tokens for u in uids]


def _drain(engine, prompts, gen: int) -> dict:
    return _drain_streams(engine, prompts, gen)[0]


def _hetero_prompts(cfg, n_requests: int, max_prompt: int) -> list[list[int]]:
    """Short-heavy heterogeneous mix: the regime where slabs waste HBM."""
    out = []
    for r in range(n_requests):
        plen = 4 + (r * 7) % max(1, max_prompt - 4)  # 4 .. max_prompt-1
        toks = jax.random.randint(
            jax.random.PRNGKey(500 + r), (plen,), 0, cfg.vocab
        )
        out.append([int(t) for t in toks])
    return out


def _sharded_sweep(
    arch: str, nm, prompt_len: int, gen: int
) -> tuple[list[dict], list[str]]:
    """Sweep 4: serve the compressed paged load tensor-parallel on an
    emulated 8-device CPU mesh, via a ``launch/serve.py`` subprocess (the
    ``--xla_force_host_platform_device_count`` flag must precede jax init,
    which this process has long passed).

    The (2,4) mesh runs twice: once on the default kernel route (the
    GSPMD-partitioned XLA gathered path on CPU) and once with
    ``REPRO_KERNEL_MODE=shard_map`` forcing the per-shard wrapper
    (``kernels.sharded``), so BENCH_serve.json captures the xla-vs-
    shard_map route comparison with per-shard roofline bytes.  Returns
    ``(records, route_parity_failures)`` — the caller asserts the greedy
    streams of the two routes match *after* persisting the records."""
    n, m = nm
    records: list[dict] = []
    failures: list[str] = []
    streams: dict[tuple[str, str], list] = {}
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    for mesh_arg, forced in (("1,1", None), ("2,4", None), ("2,4", "shard_map")):
        run_env = dict(env)
        run_env.pop("REPRO_KERNEL_MODE", None)
        if forced:
            run_env["REPRO_KERNEL_MODE"] = forced
        label = mesh_arg + (f"/{forced}" if forced else "")
        cmd = [
            sys.executable, "-m", "repro.launch.serve", "--arch", arch,
            "--nm", f"{n}:{m}", "--batch", "2",
            "--prompt-len", str(prompt_len), "--gen", str(gen),
            # 16 pages: divisible by the 4-way model axis, so the pool's
            # pages axis actually shards (sanitize_spec would otherwise
            # degrade an odd page count to a replicated pool)
            "--paged", "--page-size", "4", "--num-pages", "16",
            "--mesh", mesh_arg,
        ]
        try:
            out = subprocess.run(
                cmd, capture_output=True, text=True, env=run_env, timeout=1200
            )
        except subprocess.TimeoutExpired:
            emit(f"serve/{arch}/{n}:{m}/sharded/{label}", 0.0, "TIMEOUT")
            continue
        summary = None
        for line in out.stdout.splitlines():
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                continue
            if "summary" in d:
                summary = d["summary"]
        if summary is None:
            emit(
                f"serve/{arch}/{n}:{m}/sharded/{label}", 0.0,
                f"FAILED rc={out.returncode}: {out.stderr[-200:]}",
            )
            continue
        route = summary.get("kernel_route", "?")
        streams[(mesh_arg, route)] = summary.get("greedy_streams")
        emit(
            f"serve/{arch}/{n}:{m}/sharded/{label}",
            summary["ms_per_decode_step"] * 1e3,
            f"route={route} "
            f"w_bytes/shard={summary['weight_bytes_per_shard']} "
            f"coll_bytes={summary['decode_collective_total']:.0f} "
            f"repl_leaves={summary['replicated_weight_leaves']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "sharded_serving",
                "mesh": summary["mesh"],
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": summary["layout"],
                "batch": 2,
                "kernel_route": route,
                "us_per_decode_step": summary["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host":
                    summary["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": summary["host_overhead_frac"],
                "tokens_per_s": summary["tokens_per_s"],
                "decode_steps": summary["decode_steps"],
                "weight_bytes_per_shard": summary["weight_bytes_per_shard"],
                "cache_bytes_per_shard": summary["cache_bytes_per_shard"],
                "decode_collective_bytes": summary["decode_collective_bytes"],
                "decode_collective_total": summary["decode_collective_total"],
                "replicated_weight_leaves":
                    summary["replicated_weight_leaves"],
                # per-shard decode roofline (weight slice + split KV read)
                "model_shards": summary.get("model_shards"),
                "weight_bytes_per_step_per_shard":
                    summary.get("weight_bytes_per_step_per_shard"),
                "kv_bytes_per_step_per_shard":
                    summary.get("kv_bytes_per_step_per_shard"),
                "bytes_read_per_step_per_shard":
                    summary.get("bytes_read_per_step_per_shard"),
            }
        )
    # greedy-stream parity between the two (2,4) kernel routes: same mesh,
    # same seeds — the streams must be token-identical
    got = {r: s for (mesh_arg, r), s in streams.items() if mesh_arg == "2,4"}
    if len(got) == 2:
        a, b = got.values()
        if a is None or b is None or a != b:
            failures.append(f"2,4 routes {sorted(got)} streams differ")
        for rec in records:
            if rec["mesh"] and rec["mesh"].get("shape") == [2, 4]:
                rec["greedy_parity_across_routes"] = a is not None and a == b
    elif streams:  # one of the (2,4) runs failed outright
        failures.append(f"expected 2 routes on the 2,4 mesh, got {sorted(got)}")
    return records, failures


def _ttft_ms(engine, prompt, gen: int) -> float:
    """Wall ms from submit to the request's first sampled token (the
    engine is stepped to completion so it is clean for the next probe)."""
    import time

    sp = SamplingParams(max_new_tokens=gen)
    t0 = time.perf_counter()
    uid = engine.submit(prompt, sp)
    ttft = None
    while engine.queue or any(s is not None for s in engine.slots):
        done = engine.step()
        if ttft is None and (
            any(r.uid == uid for r in done)
            or any(
                s is not None and s.uid == uid and s.generated
                for s in engine.slots
            )
        ):
            ttft = time.perf_counter() - t0
    return (ttft if ttft is not None else time.perf_counter() - t0) * 1e3


def _prefix_int8_sweep(
    model, comp, cfg, arch: str, nm, gen: int
) -> tuple[list[dict], list[str]]:
    """Sweep 5: (a) TTFT of a prefix-index hit vs a cold admission of the
    same prompt; (b) admitted concurrency on a default-dtype vs an int8
    pool holding the *same KV HBM bytes*.  Returns (records, failures);
    failures assert only after the records persist."""
    n, m = nm
    records: list[dict] = []
    failures: list[str] = []

    # (a) TTFT: one shared 120-token head + per-request 8-token tails.  A
    # hit maps the head's pages from the radix index and prefills only the
    # tail; cold prefills everything.  Both routes are compiled untimed
    # first, and each timing is the best of 3 probes.  Every cold probe
    # clears the index first — a cold admission *inserts* its pages, so
    # without the clear the later "cold" probes would silently hit.
    ps, head_len, tail_len, pgen = 8, 120, 8, 4
    plen = head_len + tail_len
    head = [
        int(t) for t in jax.random.randint(
            jax.random.PRNGKey(7000), (head_len,), 0, cfg.vocab
        )
    ]

    def tailed(seed: int) -> list[int]:
        return head + [
            int(t) for t in jax.random.randint(
                jax.random.PRNGKey(seed), (tail_len,), 0, cfg.vocab
            )
        ]

    engine = DecodeEngine(
        model, comp, max_batch=1, max_len=plen + pgen + 1,
        num_pages=4 * ((plen + pgen) // ps + 2), page_size=ps,
        prefix_cache=True,
    )
    _ttft_ms(engine, tailed(7001), pgen)  # cold warmup (compiles prefill)
    _ttft_ms(engine, tailed(7002), pgen)  # hit warmup (compiles chunk path)
    hits0 = engine.prefix_hits
    cold = []
    for i in range(3):
        engine._prefix.clear()
        cold.append(_ttft_ms(engine, tailed(7003 + i), pgen))
    ttft_cold = min(cold)
    engine._prefix.clear()
    _ttft_ms(engine, tailed(7010), pgen)  # re-seed the index, untimed
    ttft_hit = min(_ttft_ms(engine, tailed(7011 + i), pgen) for i in range(3))
    timed_hits = engine.prefix_hits - hits0  # 3 of the 6 timed probes hit
    hit_rate = timed_hits / 6.0
    st = engine.stats()
    emit(
        f"serve/{arch}/{n}:{m}/prefix_cache/ttft",
        ttft_hit * 1e3,
        f"cold_ms={ttft_cold:.2f} hit_ms={ttft_hit:.2f} "
        f"hit_rate={hit_rate:.2f} hit_tokens={st['prefix_hit_tokens']} "
        f"cow={st['cow_copies']}",
    )
    records.append(
        {
            "suite": "serve",
            "sweep": "prefix_cache",
            "mesh": MESH_SINGLE,
            "arch": arch,
            "nm": f"{n}:{m}",
            "mode": "compressed",
            "layout": "paged",
            "prompt_len": plen,
            "shared_prefix_len": head_len,
            "ttft_cold_ms": ttft_cold,
            "ttft_hit_ms": ttft_hit,
            "ttft_speedup": ttft_cold / ttft_hit if ttft_hit else 0.0,
            "prefix_hit_rate": hit_rate,
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "cow_copies": st["cow_copies"],
            "shared_pages_peak": st["shared_pages"],
        }
    )
    if hit_rate >= 0.5 and not ttft_hit < ttft_cold:
        failures.append(
            f"prefix hit TTFT {ttft_hit:.2f}ms not under cold "
            f"{ttft_cold:.2f}ms at hit rate {hit_rate:.2f}"
        )

    # (b) admitted concurrency at equal KV HBM bytes: per-page bytes are
    # probed from each layout's live pool, then the int8 engine gets
    # however many pages fit in the default-dtype pool's byte budget.
    # fp_pages is sized so page-granular rounding of the int8 budget
    # (q_pages = floor(fp_bytes / int8_bytes_per_page)) cannot eat the
    # headline gain: at 12 fp pages a lane's 2-page steady state divides
    # both pools with at most one stranded page.
    cq_len, cq_gen, cq_ps, fp_pages, lanes = 8, 8, 8, 12, 16
    cq_max_len = cq_len + cq_gen + 1

    def probe_bpp(quant: bool) -> float:
        eng = DecodeEngine(
            model, comp, max_batch=1, max_len=cq_max_len,
            num_pages=fp_pages, page_size=cq_ps, kv_quant=quant,
        )
        return eng.kv_cache_bytes() / fp_pages

    bpp_fp, bpp_q = probe_bpp(False), probe_bpp(True)
    q_pages = int(fp_pages * bpp_fp // bpp_q)
    cq_prompts = [
        [
            int(t) for t in jax.random.randint(
                jax.random.PRNGKey(7100 + r), (cq_len,), 0, cfg.vocab
            )
        ]
        for r in range(lanes)
    ]
    conc = {}
    for label, quant, pages in (
        ("fp", False, fp_pages), ("int8", True, q_pages)
    ):
        eng = DecodeEngine(
            model, comp, max_batch=lanes, max_len=cq_max_len,
            num_pages=pages, page_size=cq_ps, kv_quant=quant,
        )
        st = _drain(eng, cq_prompts, cq_gen)
        conc[label] = st["max_concurrency"]
        emit(
            f"serve/{arch}/{n}:{m}/kv_int8/{label}",
            st["ms_per_decode_step"] * 1e3,
            f"pages={pages} kv_bytes={st['kv_cache_bytes']} "
            f"concurrency={st['max_concurrency']} "
            f"preempt={st['preemptions']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "kv_int8",
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": "paged",
                "kv_quant": quant,
                "num_pages": pages,
                "bytes_per_page": bpp_q if quant else bpp_fp,
                "kv_cache_bytes": st["kv_cache_bytes"],
                "max_concurrency": st["max_concurrency"],
                "preemptions": st["preemptions"],
                "tokens_per_s": st["tokens_per_s"],
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
            }
        )
    gain = conc["int8"] / conc["fp"] if conc.get("fp") else 0.0
    emit(
        f"serve/{arch}/{n}:{m}/kv_int8/concurrency_gain", 0.0,
        f"int8={conc.get('int8')} fp={conc.get('fp')} gain={gain:.2f}x",
    )
    if gain < 1.8:
        failures.append(
            f"int8 concurrency gain {gain:.2f}x < 1.8x at equal KV HBM "
            f"({conc})"
        )
    return records, failures


def run(
    arch: str = "gpt2-paper",
    nm=(2, 4),
    batches=(1, 2, 4),
    prompt_len: int = 8,
    gen: int = 16,
    steps_sweep=(1, 4, 8),
    out_json: str = OUT_JSON,
) -> list[dict]:
    cfg, model, sparse, comp, ratio = _serving_trees(arch, nm)
    n, m = nm
    records: list[dict] = []

    # -- sweep 1: dense vs compressed (slab), homogeneous batch ----------------
    for batch in batches:
        for mode, tree in (("dense", sparse), ("compressed", comp)):
            engine = DecodeEngine(
                model, tree, max_batch=batch, max_len=prompt_len + gen + 1
            )
            prompts = [
                [
                    int(t)
                    for t in jax.random.randint(
                        jax.random.PRNGKey(100 + r), (prompt_len,), 0, cfg.vocab
                    )
                ]
                for r in range(2 * batch)  # 2x oversubscribed: slot reuse on
            ]
            st = _drain(engine, prompts, gen)
            emit(
                f"serve/{arch}/{n}:{m}/{mode}/b{batch}",
                st["ms_per_decode_step"] * 1e3,
                f"tok/s={st['tokens_per_s']:.1f} "
                f"steps={st['decode_steps']} hbm_ratio={ratio:.3f}",
            )
            records.append(
                {
                    "suite": "serve",
                    "sweep": "dense_vs_compressed",
                    "mesh": MESH_SINGLE,
                    "arch": arch,
                    "nm": f"{n}:{m}",
                    "mode": mode,
                    "layout": "slab",
                    "batch": batch,
                    "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                    "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                    "host_overhead_frac": st["host_overhead_frac"],
                    "tokens_per_s": st["tokens_per_s"],
                    "decode_steps": st["decode_steps"],
                    "hbm_weight_ratio": ratio,
                    "kv_cache_bytes": st["kv_cache_bytes"],
                    # roofline inputs: what one decode step must read
                    "weight_bytes_per_step": st["weight_bytes_per_step"],
                    "kv_bytes_per_step": st["kv_bytes_per_step"],
                    "bytes_read_per_step": st["bytes_read_per_step"],
                }
            )

    # -- sweep 2: slab vs paged at equal HBM cache budget ----------------------
    slab_batch, page_size = 2, 8
    max_len = prompt_len + gen + 9  # headroom for the longest hetero prompt
    budget_tokens = slab_batch * max_len
    num_pages = budget_tokens // page_size
    prompts = _hetero_prompts(cfg, 6 * slab_batch, max_prompt=prompt_len + 8)
    for layout, kwargs in (
        ("slab", {"max_batch": slab_batch}),
        (
            "paged",
            {
                "max_batch": 4 * slab_batch,
                "num_pages": num_pages,
                "page_size": page_size,
            },
        ),
    ):
        engine = DecodeEngine(model, comp, max_len=max_len, **kwargs)
        st = _drain(engine, prompts, gen)
        util = st["hbm_cache_utilization"]
        emit(
            f"serve/{arch}/{n}:{m}/paged_sweep/{layout}",
            st["ms_per_decode_step"] * 1e3,
            f"tok/s={st['tokens_per_s']:.1f} "
            f"concurrency={st['max_concurrency']} util={util:.2f} "
            f"kv_bytes={st['kv_cache_bytes']} preempt={st['preemptions']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "slab_vs_paged",
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": layout,
                "batch": kwargs["max_batch"],
                "budget_tokens": budget_tokens,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": st["host_overhead_frac"],
                "tokens_per_s": st["tokens_per_s"],
                "decode_steps": st["decode_steps"],
                "max_concurrency": st["max_concurrency"],
                "preemptions": st["preemptions"],
                "hbm_weight_ratio": ratio,
                "kv_cache_bytes": st["kv_cache_bytes"],
                "hbm_cache_utilization": util,
                "weight_bytes_per_step": st["weight_bytes_per_step"],
                "kv_bytes_per_step": st["kv_bytes_per_step"],
                "bytes_read_per_step": st["bytes_read_per_step"],
            }
        )

    paged_rec = next(r for r in records if r.get("layout") == "paged")
    slab_rec = next(
        r for r in records if r.get("sweep") == "slab_vs_paged"
        and r["layout"] == "slab"
    )
    emit(
        f"serve/{arch}/{n}:{m}/paged_sweep/concurrency_gain",
        0.0,
        f"paged={paged_rec['max_concurrency']} slab={slab_rec['max_concurrency']}",
    )

    # -- sweep 3: steps-per-dispatch (fused K-step decode, donated caches) -----
    k_batch, k_page_size = 2, 8
    k_max_len = prompt_len + gen + 1
    k_pages = 2 * k_batch * (-(-k_max_len // k_page_size))
    k_prompts = [
        [
            int(t)
            for t in jax.random.randint(
                jax.random.PRNGKey(900 + r), (prompt_len,), 0, cfg.vocab
            )
        ]
        for r in range(2 * k_batch)
    ]
    _, base_streams = _drain_streams(
        DecodeEngine(
            model, comp, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size, donate=False,
        ),
        k_prompts, gen,
    )
    parity_failures: list[int] = []
    fixedk_st: dict = {}
    for k in steps_sweep:
        engine = DecodeEngine(
            model, comp, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size,
            steps_per_dispatch=k, donate=True,
        )
        st, streams = _drain_streams(engine, k_prompts, gen)
        parity = streams == base_streams
        if not parity:
            parity_failures.append(k)
        emit(
            f"serve/{arch}/{n}:{m}/steps_per_dispatch/k{k}",
            st["ms_per_decode_step"] * 1e3,
            f"host_us/tok={st['ms_per_decode_step_host'] * 1e3:.1f} "
            f"host_frac={st['host_overhead_frac']:.3f} "
            f"syncs={st['host_syncs']} parity={parity}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "steps_per_dispatch",
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": "paged",
                "batch": k_batch,
                "steps_per_dispatch": k,
                "donate": True,
                "greedy_parity_with_k1": parity,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                "host_overhead_frac": st["host_overhead_frac"],
                "host_syncs": st["host_syncs"],
                "decode_steps": st["decode_steps"],
                "tokens_per_s": st["tokens_per_s"],
                "table_full_uploads": st["table_full_uploads"],
                "table_row_syncs": st["table_row_syncs"],
                "table_syncs": st["table_syncs"],
            }
        )
        if k == max(steps_sweep):
            fixedk_st = st

    # -- sweep 3b: device-resident scheduler (run-until-stop + async) ----------
    # Same workload as sweep 3, but the while-loop scheduler: the host only
    # syncs at full-drain cycle boundaries (refill staging keeps lanes busy
    # in between), and async double-buffers the token-block fetch.  Streams
    # must stay bit-identical to the K=1 sync baseline; host µs/token must
    # beat the best fixed-K dispatch above.
    k_dev = max(steps_sweep)
    for variant, kw in (
        ("device", dict(max_steps_per_dispatch=k_dev)),
        (
            "device_async",
            dict(
                max_steps_per_dispatch=k_dev,
                staged_lanes=k_batch,
                async_stream=True,
            ),
        ),
    ):
        engine = DecodeEngine(
            model, comp, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size, donate=True, **kw,
        )
        st, streams = _drain_streams(engine, k_prompts, gen)
        parity = streams == base_streams
        if not parity:
            parity_failures.append(variant)
        # one host sync should buy >> 1 token: amortized syncs per token
        # lands well under the 1/(K*batch) a fixed-K dispatch pays
        syncs_per_tok = (
            st["host_syncs"] / st["decode_tokens"]
            if st["decode_tokens"] else float("inf")
        )
        emit(
            f"serve/{arch}/{n}:{m}/device_scheduler/{variant}",
            st["ms_per_decode_step"] * 1e3,
            f"host_us/tok={st['ms_per_decode_step_host'] * 1e3:.1f} "
            f"syncs={st['host_syncs']} syncs/tok={syncs_per_tok:.4f} "
            f"refills={st['refills']} itl_p50={st['itl_ms_p50']:.2f}ms "
            f"itl_p99={st['itl_ms_p99']:.2f}ms parity={parity}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "device_scheduler",
                "variant": variant,
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": "paged",
                "batch": k_batch,
                "max_steps_per_dispatch": k_dev,
                "staged_lanes": st["staged_lanes"],
                "async_stream": st["async_stream"],
                "donate": True,
                "greedy_parity_with_k1": parity,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "us_per_decode_step_host": st["ms_per_decode_step_host"] * 1e3,
                "us_per_decode_step_host_fixedk": (
                    fixedk_st["ms_per_decode_step_host"] * 1e3
                ),
                "host_overhead_frac": st["host_overhead_frac"],
                "host_syncs": st["host_syncs"],
                "host_syncs_per_token": syncs_per_tok,
                "cycles": st["cycles"],
                "dispatches": st["dispatches"],
                "block_fetches": st["block_fetches"],
                "refills": st["refills"],
                "itl_ms_p50": st["itl_ms_p50"],
                "itl_ms_p99": st["itl_ms_p99"],
                "decode_steps": st["decode_steps"],
                "decode_tokens": st["decode_tokens"],
                "tokens_per_s": st["tokens_per_s"],
            }
        )

    # -- sweep 4: sharded serving on an emulated 8-device CPU mesh -------------
    sharded_records, route_failures = _sharded_sweep(arch, nm, prompt_len, gen)
    records.extend(sharded_records)

    # -- sweep 5: prefix caching + int8 KV pages -------------------------------
    prefix_records, prefix_failures = _prefix_int8_sweep(
        model, comp, cfg, arch, nm, gen
    )
    records.extend(prefix_records)

    # -- sweep 6: self-speculative decoding vs fused K-step decode -------------
    # Same paged workload as sweep 3.  Two drafter/verifier pairings:
    # "self" (drafter == verifier == compressed: acceptance is 1.0 by
    # construction, so gamma+1 tokens commit per host sync — the
    # apples-to-apples sync-amortization comparison against the K=8 fused
    # baseline, asserted below) and "cross" (compressed drafter,
    # masked-dense verifier — the honest two-fidelity pairing; its
    # acceptance rate on *untrained* weights is recorded, not asserted).
    # Greedy streams must match a plain engine serving the verifier tree
    # (the losslessness guarantee), both pairings.
    # gamma = 2K: with full acceptance one draft+verify round commits a
    # whole request's remaining budget, so syncs/accepted-token lands
    # strictly under the fused baseline (gamma = K would only *tie* it —
    # the budget-truncated last round gives back the +1 bonus advantage)
    spec_g = 2 * max(steps_sweep)
    fixedk_syncs_per_tok = (
        fixedk_st["host_syncs"] / fixedk_st["decode_tokens"]
        if fixedk_st["decode_tokens"] else float("inf")
    )
    _, sparse_streams = _drain_streams(
        DecodeEngine(
            model, sparse, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size, donate=False,
        ),
        k_prompts, gen,
    )
    spec_failures: list[str] = []
    for variant, draft_tree, verify_tree, verify_streams in (
        ("self", comp, comp, base_streams),
        ("cross", comp, sparse, sparse_streams),
    ):
        engine = DecodeEngine(
            model, draft_tree, max_batch=k_batch, max_len=k_max_len,
            num_pages=k_pages, page_size=k_page_size, donate=True,
            spec_gamma=spec_g, verify_params=verify_tree,
        )
        st, streams = _drain_streams(engine, k_prompts, gen)
        parity = streams == verify_streams
        if not parity:
            spec_failures.append(f"{variant}:parity")
        syncs_per_acc = (
            st["host_syncs"] / st["spec_emitted_tokens"]
            if st["spec_emitted_tokens"] else float("inf")
        )
        emit(
            f"serve/{arch}/{n}:{m}/speculative/{variant}",
            st["ms_per_decode_step"] * 1e3,
            f"gamma={spec_g} accept={st['acceptance_rate']:.3f} "
            f"acc/verify={st['accepted_per_verify']:.2f} "
            f"syncs/tok={syncs_per_acc:.4f} "
            f"(k8={fixedk_syncs_per_tok:.4f}) parity={parity}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "speculative",
                "variant": variant,
                "mesh": MESH_SINGLE,
                "arch": arch,
                "nm": f"{n}:{m}",
                "layout": "paged",
                "batch": k_batch,
                "spec_gamma": spec_g,
                "spec_rounds": st["spec_rounds"],
                "draft_tokens": st["draft_tokens"],
                "verify_tokens": st["verify_tokens"],
                "accepted_draft_tokens": st["accepted_draft_tokens"],
                "acceptance_rate": st["acceptance_rate"],
                "accepted_per_verify": st["accepted_per_verify"],
                "bytes_per_accepted_token": st["bytes_per_accepted_token"],
                "host_syncs": st["host_syncs"],
                "host_syncs_per_accepted_token": syncs_per_acc,
                "host_syncs_per_token_fixedk": fixedk_syncs_per_tok,
                "greedy_parity_with_verifier": parity,
                "tokens_per_s": st["tokens_per_s"],
            }
        )
        if variant == "self" and not syncs_per_acc < fixedk_syncs_per_tok:
            spec_failures.append(
                f"self: {syncs_per_acc:.4f} syncs/accepted-token not "
                f"under the K={max(steps_sweep)} baseline "
                f"{fixedk_syncs_per_tok:.4f}"
            )

    if out_json:
        # schema note: documents the mesh field + per-shard / prefix-cache
        # columns; upserted so the note tracks the current schema exactly
        _upsert_schema_note(out_json)
        append_json(out_json, records)
    # fail *after* persisting: a parity break must not discard the run's
    # records (the greedy_parity_with_k1 / greedy_parity_across_routes
    # fields mark the offending rows)
    assert not parity_failures, (
        "fused/device-scheduler decode diverged from the K=1 baseline at "
        f"{parity_failures}"
    )
    assert not route_failures, (
        f"xla vs shard_map kernel routes diverged: {route_failures}"
    )
    assert not prefix_failures, (
        f"prefix-cache/int8 sweep regressions: {prefix_failures}"
    )
    assert not spec_failures, (
        f"speculative sweep regressions: {spec_failures}"
    )
    return records
