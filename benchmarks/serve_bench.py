"""Serving-engine benchmark: dense vs compressed, slab vs paged KV cache.

Two sweeps through ``repro.serving.DecodeEngine``:

1. **dense vs compressed** (slab layout, homogeneous prompts): the same
   request load served on the masked-dense tree and on the N:M-compressed
   tree (the ``nm_spmm`` dispatch path), reporting µs/decode-step plus
   tokens/s and the HBM weight-bytes ratio.  On CPU dispatch selects the
   vectorized XLA path (``kernels.nm_spmm.nm_spmm_xla``): at smoke sizes
   compressed decode matches-or-beats dense at batch 1 and stays within
   2x above (was 8x slower on the seed's scatter-decompress route); the
   HBM ratio column is the quantity the TPU Pallas kernel converts into
   decode-step time.

Each record also carries the decode-step roofline inputs
(``weight_bytes_per_step`` / ``kv_bytes_per_step`` /
``bytes_read_per_step``): what one step must stream from HBM, with
compressed leaves at stored size and only *live* KV tokens counted (the
paged fast path's read set).

2. **slab vs paged** (compressed tree, heterogeneous prompt lengths): the
   slab engine allocates ``max_batch × max_len`` token slots per layer no
   matter the request mix; the paged engine is given the *same HBM cache
   budget* (``num_pages × page_size == max_batch × max_len``) but hands
   pages out block-granularly, so short requests stop reserving worst-case
   slabs and more requests decode concurrently.  Reported per engine:
   admitted concurrency, KV-cache bytes, cache token-utilization,
   preemptions, tokens/s.

Every row is also appended to a machine-readable ``BENCH_serve.json``
(list of record dicts) so the perf trajectory accumulates across runs.

    PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import jax

import repro.core as core
from benchmarks.common import append_json, emit
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params, compression_report

OUT_JSON = "BENCH_serve.json"


def _serving_trees(arch: str, nm):
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n, m = nm
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)
    comp = compress_params(sparse, recipe.sparsity)
    ratio = compression_report(sparse, comp)["ratio"]
    return cfg, model, sparse, comp, ratio


def _drain(engine, prompts, gen: int) -> dict:
    sp = SamplingParams(max_new_tokens=gen)
    for p in prompts:
        engine.submit(p, sp)
    engine.run()
    return engine.stats()


def _hetero_prompts(cfg, n_requests: int, max_prompt: int) -> list[list[int]]:
    """Short-heavy heterogeneous mix: the regime where slabs waste HBM."""
    out = []
    for r in range(n_requests):
        plen = 4 + (r * 7) % max(1, max_prompt - 4)  # 4 .. max_prompt-1
        toks = jax.random.randint(
            jax.random.PRNGKey(500 + r), (plen,), 0, cfg.vocab
        )
        out.append([int(t) for t in toks])
    return out


def run(
    arch: str = "gpt2-paper",
    nm=(2, 4),
    batches=(1, 2, 4),
    prompt_len: int = 8,
    gen: int = 16,
    out_json: str = OUT_JSON,
) -> list[dict]:
    cfg, model, sparse, comp, ratio = _serving_trees(arch, nm)
    n, m = nm
    records: list[dict] = []

    # -- sweep 1: dense vs compressed (slab), homogeneous batch ----------------
    for batch in batches:
        for mode, tree in (("dense", sparse), ("compressed", comp)):
            engine = DecodeEngine(
                model, tree, max_batch=batch, max_len=prompt_len + gen + 1
            )
            prompts = [
                [
                    int(t)
                    for t in jax.random.randint(
                        jax.random.PRNGKey(100 + r), (prompt_len,), 0, cfg.vocab
                    )
                ]
                for r in range(2 * batch)  # 2x oversubscribed: slot reuse on
            ]
            st = _drain(engine, prompts, gen)
            emit(
                f"serve/{arch}/{n}:{m}/{mode}/b{batch}",
                st["ms_per_decode_step"] * 1e3,
                f"tok/s={st['tokens_per_s']:.1f} "
                f"steps={st['decode_steps']} hbm_ratio={ratio:.3f}",
            )
            records.append(
                {
                    "suite": "serve",
                    "sweep": "dense_vs_compressed",
                    "arch": arch,
                    "nm": f"{n}:{m}",
                    "mode": mode,
                    "layout": "slab",
                    "batch": batch,
                    "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                    "tokens_per_s": st["tokens_per_s"],
                    "decode_steps": st["decode_steps"],
                    "hbm_weight_ratio": ratio,
                    "kv_cache_bytes": st["kv_cache_bytes"],
                    # roofline inputs: what one decode step must read
                    "weight_bytes_per_step": st["weight_bytes_per_step"],
                    "kv_bytes_per_step": st["kv_bytes_per_step"],
                    "bytes_read_per_step": st["bytes_read_per_step"],
                }
            )

    # -- sweep 2: slab vs paged at equal HBM cache budget ----------------------
    slab_batch, page_size = 2, 8
    max_len = prompt_len + gen + 9  # headroom for the longest hetero prompt
    budget_tokens = slab_batch * max_len
    num_pages = budget_tokens // page_size
    prompts = _hetero_prompts(cfg, 6 * slab_batch, max_prompt=prompt_len + 8)
    for layout, kwargs in (
        ("slab", {"max_batch": slab_batch}),
        (
            "paged",
            {
                "max_batch": 4 * slab_batch,
                "num_pages": num_pages,
                "page_size": page_size,
            },
        ),
    ):
        engine = DecodeEngine(model, comp, max_len=max_len, **kwargs)
        st = _drain(engine, prompts, gen)
        util = st["hbm_cache_utilization"]
        emit(
            f"serve/{arch}/{n}:{m}/paged_sweep/{layout}",
            st["ms_per_decode_step"] * 1e3,
            f"tok/s={st['tokens_per_s']:.1f} "
            f"concurrency={st['max_concurrency']} util={util:.2f} "
            f"kv_bytes={st['kv_cache_bytes']} preempt={st['preemptions']}",
        )
        records.append(
            {
                "suite": "serve",
                "sweep": "slab_vs_paged",
                "arch": arch,
                "nm": f"{n}:{m}",
                "mode": "compressed",
                "layout": layout,
                "batch": kwargs["max_batch"],
                "budget_tokens": budget_tokens,
                "us_per_decode_step": st["ms_per_decode_step"] * 1e3,
                "tokens_per_s": st["tokens_per_s"],
                "decode_steps": st["decode_steps"],
                "max_concurrency": st["max_concurrency"],
                "preemptions": st["preemptions"],
                "hbm_weight_ratio": ratio,
                "kv_cache_bytes": st["kv_cache_bytes"],
                "hbm_cache_utilization": util,
                "weight_bytes_per_step": st["weight_bytes_per_step"],
                "kv_bytes_per_step": st["kv_bytes_per_step"],
                "bytes_read_per_step": st["bytes_read_per_step"],
            }
        )

    paged_rec = next(r for r in records if r.get("layout") == "paged")
    slab_rec = next(
        r for r in records if r.get("sweep") == "slab_vs_paged"
        and r["layout"] == "slab"
    )
    emit(
        f"serve/{arch}/{n}:{m}/paged_sweep/concurrency_gain",
        0.0,
        f"paged={paged_rec['max_concurrency']} slab={slab_rec['max_concurrency']}",
    )

    if out_json:
        append_json(out_json, records)
    return records
