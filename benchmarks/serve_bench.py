"""Serving-engine benchmark: dense vs compressed-native decode, batch sweep.

For each batch size the same request load is served twice through
``repro.serving.DecodeEngine`` — once on the masked-dense tree, once on the
N:M-compressed tree (the ``nm_spmm`` dispatch path) — and we report
µs/decode-step (the ``us_per_call`` column) plus tokens/s and the HBM
weight-bytes ratio. On CPU the compressed path pays a decompress per
matmul (the jnp reference); the HBM ratio column is the quantity the TPU
Pallas kernel converts into decode-step time.

    PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import jax

import repro.core as core
from benchmarks.common import emit
from repro.configs import get_config
from repro.models.model import TransformerLM
from repro.serving import DecodeEngine, SamplingParams
from repro.sparse_infer import compress_params, compression_report


def run(
    arch: str = "gpt2-paper",
    nm=(2, 4),
    batches=(1, 2, 4),
    prompt_len: int = 8,
    gen: int = 16,
) -> None:
    cfg = get_config(arch, smoke=True)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n, m = nm
    recipe = core.make_recipe(
        "step", core.SparsityConfig(default=core.NMSparsity(n, m))
    )
    sparse = recipe.export_sparse(params)
    comp = compress_params(sparse, recipe.sparsity)
    ratio = compression_report(sparse, comp)["ratio"]

    for batch in batches:
        for mode, tree in (("dense", sparse), ("compressed", comp)):
            engine = DecodeEngine(
                model, tree, max_batch=batch, max_len=prompt_len + gen + 1
            )
            sp = SamplingParams(max_new_tokens=gen)
            for r in range(2 * batch):  # 2x oversubscribed: slot reuse on
                prompt = jax.random.randint(
                    jax.random.PRNGKey(100 + r), (prompt_len,), 0, cfg.vocab
                )
                engine.submit([int(t) for t in prompt], sp)
            engine.run()
            st = engine.stats()
            emit(
                f"serve/{arch}/{n}:{m}/{mode}/b{batch}",
                st["ms_per_decode_step"] * 1e3,
                f"tok/s={st['tokens_per_s']:.1f} "
                f"steps={st['decode_steps']} hbm_ratio={ratio:.3f}",
            )
