"""Kernel microbenchmarks: nm_mask / nm_spmm vs jnp reference.

CPU wall-times of the jitted *reference* paths (the production CPU path),
plus interpret-mode correctness deltas for the Pallas kernels (TPU-target
timing is structural — see §Roofline; interpret mode timing is meaningless
and not reported as perf).

Derived column reports the analytic HBM-traffic ratio of the compressed
serving matmul — the quantity the TPU kernel exists to win (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import masking
from repro.kernels import ref
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas


def bench_mask(shapes=((1024, 1024), (4096, 1024)), nm=((2, 4), (1, 8))):
    for shape in shapes:
        for n, m in nm:
            w = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
            f = jax.jit(functools.partial(masking.nm_mask_and_apply, n=n, m=m))
            us = time_fn(f, w)
            # correctness of the Pallas kernel against this reference
            masked, mask = nm_mask_apply_pallas(w, n, m, interpret=True)
            ok = bool(jnp.array_equal(mask, masking.nm_mask(w, n, m, 0)))
            emit(
                f"kernel_nm_mask/{shape[0]}x{shape[1]}/{n}:{m}",
                us,
                f"pallas_match={ok}",
            )


def bench_spmm(cases=((64, 2048, 2048), (8, 4096, 4096))):
    for b, k, o in cases:
        for n, m in ((2, 4), (1, 4)):
            x = jax.random.normal(jax.random.PRNGKey(0), (b, k), jnp.float32)
            w = jax.random.normal(jax.random.PRNGKey(1), (k, o), jnp.float32)
            v, i = ref.nm_compress(w, n, m, 0)
            fr = jax.jit(functools.partial(ref.nm_spmm_ref, n=n, m=m))
            us = time_fn(fr, x, v, i)
            y = nm_spmm_pallas(x[:8], v, i, n, m, interpret=True)
            err = float(jnp.max(jnp.abs(y - ref.nm_spmm_ref(x[:8], v, i, n, m))))
            # HBM weight-traffic ratio on TPU: (n/m * bits + n/m * 8) / bits
            bits = 16
            traffic = (n / m) * (bits + 8) / bits
            emit(
                f"kernel_nm_spmm/{b}x{k}x{o}/{n}:{m}",
                us,
                f"pallas_err={err:.1e};tpu_weight_traffic_ratio={traffic:.3f}",
            )


def run() -> None:
    bench_mask()
    bench_spmm()


if __name__ == "__main__":
    run()
