"""Kernel microbenchmarks: nm_mask / nm_spmm / paged_attn vs references.

CPU wall-times of the jitted *production CPU* paths (the XLA routes the
dispatch layer selects off-TPU), plus interpret-mode correctness deltas for
the Pallas kernels (TPU-target timing is structural — see §Roofline;
interpret mode timing is meaningless and not reported as perf).

Derived columns report the analytic HBM-traffic quantities the TPU kernels
exist to win: the compressed-matmul weight ratio (DESIGN.md §3) and the
live-pages-vs-full-gather byte ratio of paged decode attention.  The
paged-attn sweep also appends machine-readable records to
``BENCH_paged_attn.json``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_json, emit, time_fn
from repro.core import masking
from repro.kernels import ref
from repro.kernels.nm_mask import nm_mask_apply_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas, nm_spmm_xla
from repro.kernels.paged_attn import paged_attn_pallas, paged_attn_xla
from repro.models.layers import decode_attention

PAGED_OUT_JSON = "BENCH_paged_attn.json"


def bench_mask(shapes=((1024, 1024), (4096, 1024)), nm=((2, 4), (1, 8))):
    for shape in shapes:
        for n, m in nm:
            w = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
            f = jax.jit(functools.partial(masking.nm_mask_and_apply, n=n, m=m))
            us = time_fn(f, w)
            # correctness of the Pallas kernel against this reference
            masked, mask = nm_mask_apply_pallas(w, n, m, interpret=True)
            ok = bool(jnp.array_equal(mask, masking.nm_mask(w, n, m, 0)))
            emit(
                f"kernel_nm_mask/{shape[0]}x{shape[1]}/{n}:{m}",
                us,
                f"pallas_match={ok}",
            )


def bench_spmm(cases=((64, 2048, 2048), (8, 4096, 4096))):
    for b, k, o in cases:
        for n, m in ((2, 4), (1, 4)):
            x = jax.random.normal(jax.random.PRNGKey(0), (b, k), jnp.float32)
            w = jax.random.normal(jax.random.PRNGKey(1), (k, o), jnp.float32)
            v, i = ref.nm_compress(w, n, m, 0)
            fr = jax.jit(functools.partial(ref.nm_spmm_ref, n=n, m=m))
            us = time_fn(fr, x, v, i)
            y = nm_spmm_pallas(x[:8], v, i, n, m, interpret=True)
            err = float(jnp.max(jnp.abs(y - ref.nm_spmm_ref(x[:8], v, i, n, m))))
            # HBM weight-traffic ratio on TPU: (n/m * bits + n/m * 8) / bits
            bits = 16
            traffic = (n / m) * (bits + 8) / bits
            emit(
                f"kernel_nm_spmm/{b}x{k}x{o}/{n}:{m}",
                us,
                f"pallas_err={err:.1e};tpu_weight_traffic_ratio={traffic:.3f}",
            )


def bench_spmm_xla(cases=((1, 2048, 2048), (64, 2048, 2048))):
    """The dispatch-selected CPU path (gather / decompress regimes) vs the
    dense matmul it must beat-or-match off-TPU."""
    for b, k, o in cases:
        x = jax.random.normal(jax.random.PRNGKey(0), (b, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, o), jnp.float32)
        v, i = ref.nm_compress(w, 2, 4, 0)
        us_d = time_fn(jax.jit(lambda x, w: x @ w), x, w)
        us_c = time_fn(
            jax.jit(functools.partial(nm_spmm_xla, n=2, m=4)), x, v, i
        )
        emit(
            f"kernel_nm_spmm_xla/{b}x{k}x{o}/2:4",
            us_c,
            f"dense_us={us_d:.1f};ratio={us_c / us_d:.2f}",
        )


def _paged_case(seed, b, hkv, g, d, ps, n_slots, lens):
    """Random pool + append-only tables for heterogeneous lane lengths."""
    live = sum(-(-ln // ps) for ln in lens)
    num_pages = live + 2
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, hkv, g, d), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, ps, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, ps, hkv, d), jnp.float32)
    t = np.full((b, n_slots), num_pages, np.int32)
    nxt = 0
    for i, ln in enumerate(lens):
        for pg in range(-(-ln // ps)):
            t[i, pg] = nxt
            nxt += 1
    return q, kp, vp, jnp.asarray(t), jnp.asarray(lens, jnp.int32), num_pages


def bench_paged_attn(out_json: str = PAGED_OUT_JSON) -> list[dict]:
    """Paged decode attention: table-direct kernel vs the full-view gather.

    CPU times compare the two *XLA* formulations (production off-TPU): the
    legacy contiguous ``(B, S_max, ...)`` gather + ``decode_attention``
    against the kernel's oracle.  The structural quantity is the bytes
    column: live pages touched per step vs the full logical view the
    gather materializes — on TPU that ratio bounds the kernel's win.
    Interpret-mode parity of the Pallas kernel is asserted per case.
    """
    records: list[dict] = []
    cases = [
        # (B, Hkv, G, D, ps, max_len, mean fill fraction)
        (4, 2, 4, 64, 16, 256, 0.25),
        (8, 2, 4, 64, 16, 512, 0.125),
        (8, 1, 8, 128, 16, 512, 0.25),
    ]
    for b, hkv, g, d, ps, max_len, fill in cases:
        n_slots = max_len // ps
        lens = [
            max(1, int(max_len * fill * (0.5 + (i % 4) / 2))) for i in range(b)
        ]
        q, kp, vp, tables, lengths, num_pages = _paged_case(
            7, b, hkv, g, d, ps, n_slots, lens
        )
        scale = d ** -0.5

        def gathered(q, kp, vp, tables, lengths):
            phys = jnp.minimum(tables, num_pages - 1)
            kv = kp[phys].reshape(b, n_slots * ps, hkv, d)
            vv = vp[phys].reshape(b, n_slots * ps, hkv, d)
            return decode_attention(
                q.reshape(b, 1, hkv * g, d), kv, vv, lengths
            )

        f_gather = jax.jit(gathered)
        f_kernel = jax.jit(functools.partial(paged_attn_xla, scale=scale))
        us_gather = time_fn(f_gather, q, kp, vp, tables, lengths)
        us_kernel = time_fn(f_kernel, q, kp, vp, tables, lengths)
        y_itp = paged_attn_pallas(
            q, kp, vp, tables, lengths, scale=scale, interpret=True
        )
        err = float(
            jnp.max(jnp.abs(y_itp - f_kernel(q, kp, vp, tables, lengths)))
        )
        assert err < 1e-4, f"paged_attn interpret parity broke: {err:.1e}"
        row_bytes = 2 * hkv * d * 4  # K+V f32
        bytes_live = sum(-(-ln // ps) for ln in lens) * ps * row_bytes
        bytes_gather = b * n_slots * ps * row_bytes
        name = f"kernel_paged_attn/b{b}h{hkv}g{g}d{d}/ps{ps}x{n_slots}"
        emit(
            name,
            us_kernel,
            f"gather_us={us_gather:.1f};pallas_err={err:.1e};"
            f"bytes_live={bytes_live};bytes_gather={bytes_gather};"
            f"byte_ratio={bytes_live / bytes_gather:.3f}",
        )
        records.append(
            {
                "suite": "paged_attn",
                "case": name,
                "batch": b,
                "heads_kv": hkv,
                "group": g,
                "head_dim": d,
                "page_size": ps,
                "n_slots": n_slots,
                "lane_lens": lens,
                "us_kernel_xla": us_kernel,
                "us_full_gather": us_gather,
                "pallas_interpret_err": err,
                "kv_bytes_live_per_step": bytes_live,
                "kv_bytes_full_gather": bytes_gather,
                "kv_byte_ratio": bytes_live / bytes_gather,
            }
        )
    if out_json:
        append_json(out_json, records)
    return records


def run() -> None:
    bench_mask()
    bench_spmm()
    bench_spmm_xla()
    bench_paged_attn()


if __name__ == "__main__":
    run()
