"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
The serving suite additionally appends machine-readable records to
``BENCH_serve.json`` (batch, µs/decode-step, tokens/s, HBM ratios,
slab-vs-paged concurrency) so the perf trajectory accumulates across runs.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="shorter training runs")
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()
    steps = 150 if args.quick else 400

    from benchmarks import (
        ablations,
        autoswitch_bench,
        kernel_bench,
        layerwise,
        recipes,
        roofline,
        serve_bench,
        sparsity_sweep,
    )

    suites = {
        "kernels": kernel_bench.run,                       # §Kernels
        "serve": serve_bench.run,                          # §Serving engine
        "autoswitch": lambda: autoswitch_bench.run(steps=max(300, steps)),  # Table 1
        "recipes": lambda: (recipes.table_mlp(steps=steps, seeds=(0,)),
                            recipes.table_lm(steps=120)),  # Tables 2-3
        "sparsity_sweep": lambda: sparsity_sweep.run(steps=120),            # Fig 5
        "layerwise": lambda: layerwise.run(steps=120),                      # Table 4
        "ablations": ablations.run,                                         # Figs 6-8
        "roofline": roofline.run,                                           # §Roofline
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# suite: {name}", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running
            print(f"{name}/FAILED,0.0,{type(e).__name__}:{e}", flush=True)
    print(f"# total wall: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
