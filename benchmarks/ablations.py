"""Paper Figures 6-8: the three ablation studies.

- Fig 6 (Decaying Mask): decay recipe with vs without its dense warmup phase
  (controlled task; the effect is recipe-structural).
- Fig 7 (phase length): STEP with fixed switch points across training (LM).
- Fig 8 (why freeze v): STEP vs STEP-with-live-variance in phase 2 (LM).
"""
from __future__ import annotations

from benchmarks.common import emit, train_lm_recipe, train_mlp_recipe


def fig6_decay_dense_phase(steps=400) -> dict:
    out = {}
    for label, dense_until in (("with_dense", int(0.2 * steps)), ("no_dense", 0)):
        r = train_mlp_recipe("decay", steps=steps, seed=0, dense_until=dense_until)
        out[label] = r["sparse_eval_loss"]
        emit(
            f"ablation_decay/{label}",
            r["us_per_step"],
            f"sparse_eval_loss={r['sparse_eval_loss']:.4f}",
        )
    return out


def fig7_phase_length(steps=120) -> dict:
    out = {}
    for frac in (0.1, 0.5, 0.8):
        r = train_lm_recipe("step", steps=steps, seed=0, switch_at=int(frac * steps))
        out[frac] = r["sparse_eval_loss"]
        emit(
            f"ablation_phase_length/{frac:.2f}",
            r["us_per_step"],
            f"sparse_eval_loss={r['sparse_eval_loss']:.4f}",
        )
    return out


def fig8_frozen_variance(steps=120) -> dict:
    out = {}
    for label, live in (("frozen_v", False), ("live_v", True)):
        r = train_lm_recipe(
            "step", steps=steps, seed=0, switch_at=int(0.25 * steps),
            update_v_in_phase2=live,
        )
        out[label] = r["sparse_eval_loss"]
        emit(
            f"ablation_variance/{label}",
            r["us_per_step"],
            f"sparse_eval_loss={r['sparse_eval_loss']:.4f}",
        )
    return out


def run() -> None:
    fig6_decay_dense_phase()
    fig7_phase_length()
    fig8_frozen_variance()


if __name__ == "__main__":
    run()
