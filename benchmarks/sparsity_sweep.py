"""Paper Figure 5: robustness to aggressive sparsity ratios.

SR-STE vs STEP at 2:4, 1:8, 1:16 on the GPT-2-family LM (Adam + attention —
the paper's regime; the tiny teacher-student task is too benign to expose
the variance pathology at aggressive ratios). Claim to reproduce: STEP
degrades gracefully while SR-STE falls off first.
"""
from __future__ import annotations

from benchmarks.common import emit, train_lm_recipe

RATIOS = [(2, 4), (1, 8), (1, 16)]


def run(steps=120) -> dict:
    out = {}
    for n, m in RATIOS:
        for kind in ("sr_ste", "step"):
            r = train_lm_recipe(kind, n=n, m=m, steps=steps, seed=0)
            out[(kind, f"{n}:{m}")] = r["sparse_eval_loss"]
            emit(
                f"sparsity_sweep/{kind}/{n}:{m}",
                r["us_per_step"],
                f"sparse_eval_loss={r['sparse_eval_loss']:.4f}",
            )
    return out


if __name__ == "__main__":
    run()
