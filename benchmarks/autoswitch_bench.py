"""Paper Table 1: switching-point quality — Eq.(10) vs Eq.(11) vs AutoSwitch.

Profiles ||v_t||_2, ||v_t||_1 and ||v_{t+1}-v_t||_1 along a dense-Adam
trajectory (exactly the paper's protocol), lets each criterion pick its t0,
then scores each by the average variance change over the following window:
score(t0) = W^{-1} * sum_{t=t0..t0+W} ||v_{t+1} - v_t||_1 (lower = better
preconditioning). The paper uses W=1000 on full tasks; we scale W to the
short CPU trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from benchmarks.common import emit
from repro.data import SyntheticTask
from repro.optim.adam import adam
from repro.optim.base import apply_updates


def profile_trajectory(steps=600, seed=0, b2=0.99):
    task = SyntheticTask(seed=seed)
    opt = adam(3e-3, b2=b2)
    params = task.student_init(jax.random.PRNGKey(seed))
    state = opt.init(params)
    l2, l1, dl1, zs = [], [], [], []
    d = sum(x.size for x in jax.tree_util.tree_leaves(params))

    @jax.jit
    def one(params, state, x, y):
        g = jax.grad(lambda p: task.loss(p, x, y))(params)
        v_old = state.v
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
        diff = sum(
            jnp.sum(jnp.abs(a - b))
            for a, b in zip(
                jax.tree_util.tree_leaves(state.v), jax.tree_util.tree_leaves(v_old)
            )
        )
        n2 = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(state.v)))
        n1 = sum(jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(state.v))
        return params, state, diff, n2, n1

    for t in range(steps):
        x, y = task.batch(t, 64)
        params, state, diff, n2, n1 = one(params, state, x, y)
        l2.append(float(n2)); l1.append(float(n1)); dl1.append(float(diff))
        zs.append(float(diff) / d)
    return np.array(l2), np.array(l1), np.array(dl1), np.array(zs)


def score(dl1: np.ndarray, t0: int, window: int = 100) -> float:
    end = min(len(dl1), t0 + window)
    if end <= t0:
        return float("nan")
    return float(dl1[t0:end].mean())


def run(steps=600, b2=0.99) -> dict:
    t_start = time.perf_counter()
    l2, l1, dl1, zs = profile_trajectory(steps=steps, b2=b2)
    us = (time.perf_counter() - t_start) / steps * 1e6

    t_eq10 = core.criterion_relative_norm(l2)
    t_eq11 = core.criterion_staleness(l1, beta2=b2)
    asw_cfg = core.AutoSwitchConfig(beta2=b2, eps=np.median(zs[-50:]) * 1.5)
    t_as = core.criterion_autoswitch_offline(zs, asw_cfg)

    out = {}
    for name, t0 in [("eq10_relative_norm", t_eq10),
                     ("eq11_staleness", t_eq11),
                     ("autoswitch", t_as)]:
        s = score(dl1, t0)
        out[name] = (t0, s)
        emit(f"autoswitch/{name}", us, f"t0={t0};post_switch_drift={s:.5f}")
    return out


if __name__ == "__main__":
    run()
